//! Streaming inference server: the L3 coordination contribution.
//!
//! Architecture (vLLM-shaped continuous batching, adapted to STLT's
//! O(S d) carries instead of a paged KV cache):
//!
//!   clients --> SessionHandle (open_session/feed/generate/cancel)
//!            --> BoundedQueue (admission control / backpressure)
//!            --> model thread: continuous-batching scheduler
//!                 * intake: drains new requests every iteration, so
//!                   sessions join waves mid-flight (no head-of-line
//!                   blocking behind a long generation)
//!                 * feed wave: ONE chunk for up to b_srv feeding
//!                   sessions via the `stream_batch` artifact
//!                 * decode wave: ONE token for up to b_srv generating
//!                   sessions via the batched `decode_batch` artifact
//!                   (per-row fallback on backends without it)
//!                 * fairness: the scheduler alternates one feed wave
//!                   and one decode wave per iteration, and rotates
//!                   tasks behind each wave, so no request class or
//!                   session monopolises the model thread — a decode
//!                   token waits at most one feed chunk, and vice versa
//!            --> per-request response channels; generations stream
//!                tokens through [`TokenStream`] as they are produced
//!
//! Session carries live in the StatePool ("KV-cache analog"): a session
//! with an in-flight feed or generation holds its carry checked out
//! (pinned — it can never lose state mid-wave); idle sessions are
//! LRU-evicted on admission beyond capacity. Evictions are surfaced on
//! both paths (`FeedResult::evicted`, `GenResult::evicted` +
//! `fresh_carry`). All latencies land in log-bucket histograms,
//! including time-to-first-token.

use std::collections::VecDeque;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{mpsc, Arc};

use crate::obs::{Counter, Gauge, Hist};
use crate::runtime::artifact::Entry;
use crate::runtime::exec as stlt_exec;
use crate::runtime::{BackendKind, Manifest, Runtime, StreamCarry, Tensor};
use crate::util::rng::Rng;

// Backend device handles may be !Send (xla's PJRT wraps Rc + raw
// pointers), so the model thread constructs its own Runtime and is the
// only thread to touch it; everything crossing the thread boundary is
// plain data (BackendKind is Copy + Send).

use super::batcher::BatchPolicy;
use super::queue::{BoundedQueue, PushError};
use super::sampling::Sampling;
use super::session::{
    CarrySnapshot, FinishReason, GenOpts, GenResult, SessionHandle, StreamItem, TokenStream,
};
use super::state::{Admit, Export, Import, StatePool};

/// Requests drained from the shared queue in one scheduler iteration.
/// Bounds per-iteration intake work, not concurrency: anything left
/// queued is picked up next iteration (one wave later).
const INTAKE_MAX: usize = 256;

pub struct ServerOpts {
    pub queue_cap: usize,
    pub max_sessions: usize,
    /// Legacy dynamic-batching knob. The continuous-batching scheduler
    /// forms waves from whatever is in flight each iteration, so this
    /// no longer gates batching; kept so existing configs construct.
    pub policy: BatchPolicy,
    /// Execution substrate for the model thread (default: native).
    pub backend: BackendKind,
}

impl Default for ServerOpts {
    fn default() -> Self {
        ServerOpts {
            queue_cap: 64,
            max_sessions: 16,
            policy: BatchPolicy::default(),
            backend: BackendKind::default(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct FeedResult {
    pub nll_sum: f64,
    pub count: f64,
    pub evicted: Option<u64>,
}

pub(crate) enum Request {
    Feed {
        session: u64,
        tokens: Vec<i32>,
        count_loss: bool,
        resp: mpsc::Sender<Result<FeedResult>>,
    },
    Generate { session: u64, opts: GenOpts, tx: mpsc::Sender<StreamItem> },
    Cancel { session: u64 },
    Release { session: u64 },
    /// Copy a session's carry out for migration/resume.
    ExportCarry { session: u64, resp: mpsc::Sender<Result<CarrySnapshot>> },
    /// Install an exported carry (reply: LRU-evicted victim, if any).
    ImportCarry { session: u64, snap: CarrySnapshot, resp: mpsc::Sender<Result<Option<u64>>> },
}

/// Per-server metric set, built from [`crate::obs`] primitives. The
/// handles are instance-owned (tests assert exact counts on their own
/// server) and *published* into the global registry at
/// [`Server::start`] under `server/` / `scheduler/` names — the latest
/// server wins the names, so `stlt stats` always reads the live
/// instance without any parallel bookkeeping.
pub struct ServerStats {
    pub feeds: Arc<Counter>,
    pub gens: Arc<Counter>,
    pub evictions: Arc<Counter>,
    pub shed: Arc<Counter>,
    pub cancelled: Arc<Counter>,
    pub tokens_streamed: Arc<Counter>,
    pub tokens_generated: Arc<Counter>,
    /// Wave-fill accounting (feed and decode waves alike): total waves,
    /// total active rows, and the high-water fill.
    pub waves: Arc<Counter>,
    pub wave_rows: Arc<Counter>,
    pub wave_max_fill: Arc<Gauge>,
    /// Admission-control queue: current depth + total ever parked.
    pub park_depth: Arc<Gauge>,
    pub parked_total: Arc<Counter>,
    pub feed_latency: Arc<Hist>,
    pub gen_latency: Arc<Hist>,
    /// Submission -> first streamed token, per generation.
    pub ttft_latency: Arc<Hist>,
}

impl Default for ServerStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerStats {
    pub fn new() -> ServerStats {
        ServerStats {
            feeds: Arc::new(Counter::new()),
            gens: Arc::new(Counter::new()),
            evictions: Arc::new(Counter::new()),
            shed: Arc::new(Counter::new()),
            cancelled: Arc::new(Counter::new()),
            tokens_streamed: Arc::new(Counter::new()),
            tokens_generated: Arc::new(Counter::new()),
            waves: Arc::new(Counter::new()),
            wave_rows: Arc::new(Counter::new()),
            wave_max_fill: Arc::new(Gauge::new()),
            park_depth: Arc::new(Gauge::new()),
            parked_total: Arc::new(Counter::new()),
            feed_latency: Arc::new(Hist::new()),
            gen_latency: Arc::new(Hist::new()),
            ttft_latency: Arc::new(Hist::new()),
        }
    }

    /// Record one wave of `fill` active rows.
    pub fn record_wave(&self, fill: usize) {
        self.waves.inc();
        self.wave_rows.add(fill as u64);
        self.wave_max_fill.set_max(fill as f64);
    }

    /// Mean active rows per wave.
    pub fn wave_mean_fill(&self) -> f64 {
        let waves = self.waves.get();
        if waves == 0 {
            0.0
        } else {
            self.wave_rows.get() as f64 / waves as f64
        }
    }

    /// Bind this instance's metrics into the global registry (latest
    /// publication wins; see [`crate::obs::publish`]).
    pub fn publish(&self) {
        use crate::obs::{publish, Metric};
        let c = |name: &str, m: &Arc<Counter>| publish(name, Metric::Counter(Arc::clone(m)));
        let g = |name: &str, m: &Arc<Gauge>| publish(name, Metric::Gauge(Arc::clone(m)));
        let h = |name: &str, m: &Arc<Hist>| publish(name, Metric::Hist(Arc::clone(m)));
        c("server/feeds", &self.feeds);
        c("server/gens", &self.gens);
        c("server/evictions", &self.evictions);
        c("server/shed", &self.shed);
        c("server/cancelled", &self.cancelled);
        c("server/tokens_streamed", &self.tokens_streamed);
        c("server/tokens_generated", &self.tokens_generated);
        c("server/waves", &self.waves);
        c("server/wave_rows", &self.wave_rows);
        g("server/wave_max_fill", &self.wave_max_fill);
        g("scheduler/park_depth", &self.park_depth);
        c("scheduler/parked_total", &self.parked_total);
        h("server/feed_seconds", &self.feed_latency);
        h("server/gen_seconds", &self.gen_latency);
        h("server/ttft_seconds", &self.ttft_latency);
    }
}

/// Shared client-side state behind [`Server`] and every
/// [`SessionHandle`]: the request queue, stats, and the session-id
/// allocator. Handles outlive the `Server` value only in the sense of
/// failing cleanly (the queue reports closed).
pub(crate) struct ServerCore {
    queue: Arc<BoundedQueue<(Request, Instant)>>,
    pub(crate) stats: Arc<ServerStats>,
    /// `open_session` ids start far above any hand-picked id used with
    /// the session-id API, so the two can never collide.
    next_session: AtomicU64,
}

impl ServerCore {
    fn submit(&self, req: Request) -> Result<()> {
        match self.queue.push((req, Instant::now()), Duration::from_secs(30)) {
            Ok(()) => Ok(()),
            Err(PushError::Timeout) => {
                self.stats.shed.inc();
                Err(anyhow!("server overloaded (backpressure timeout)"))
            }
            Err(PushError::Closed) => Err(anyhow!("server shut down")),
        }
    }

    pub(crate) fn feed(
        &self,
        session: u64,
        tokens: Vec<i32>,
        count_loss: bool,
    ) -> Result<FeedResult> {
        let (tx, rx) = mpsc::channel();
        self.submit(Request::Feed { session, tokens, count_loss, resp: tx })?;
        rx.recv().map_err(|_| anyhow!("model thread dropped request"))?
    }

    pub(crate) fn start_generate(&self, session: u64, opts: GenOpts) -> Result<TokenStream> {
        let (tx, rx) = mpsc::channel();
        self.submit(Request::Generate { session, opts, tx })?;
        Ok(TokenStream::new(rx))
    }

    pub(crate) fn cancel(&self, session: u64) -> Result<()> {
        self.submit(Request::Cancel { session })
    }

    pub(crate) fn release(&self, session: u64) -> Result<()> {
        self.submit(Request::Release { session })
    }

    pub(crate) fn export_carry(&self, session: u64) -> Result<CarrySnapshot> {
        let (tx, rx) = mpsc::channel();
        self.submit(Request::ExportCarry { session, resp: tx })?;
        rx.recv().map_err(|_| anyhow!("model thread dropped request"))?
    }

    pub(crate) fn import_carry(&self, session: u64, snap: CarrySnapshot) -> Result<Option<u64>> {
        let (tx, rx) = mpsc::channel();
        self.submit(Request::ImportCarry { session, snap, resp: tx })?;
        rx.recv().map_err(|_| anyhow!("model thread dropped request"))?
    }
}

pub struct Server {
    core: Arc<ServerCore>,
    pub stats: Arc<ServerStats>,
    worker: Option<thread::JoinHandle<()>>,
}

impl Server {
    /// `artifact_base` e.g. "lm_stlt_tiny"; `flat` the trained params.
    /// The runtime is created *inside* the model thread (backend device
    /// handles may be !Send); start() blocks until the executables are
    /// loaded (compiled, on the xla backend). The batched decode
    /// executable is derived from the `.decode` entry at the serving
    /// batch width; backends without the `decode_batch` kind fall back
    /// to per-row decode inside the same scheduler.
    pub fn start(
        manifest: &Manifest,
        artifact_base: &str,
        flat: Vec<f32>,
        opts: ServerOpts,
    ) -> Result<Server> {
        let stream_entry = manifest.get(&format!("{artifact_base}.stream_batch"))?.clone();
        let decode_entry = manifest.get(&format!("{artifact_base}.decode"))?.clone();
        let chunk = *stream_entry.extra.get("chunk").ok_or_else(|| anyhow!("no chunk"))? as usize;
        let b_srv =
            *stream_entry.extra.get("batch_srv").ok_or_else(|| anyhow!("no batch_srv"))? as usize;
        let vocab = decode_entry
            .outputs
            .get(2)
            .and_then(|o| o.shape.first())
            .copied()
            .ok_or_else(|| anyhow!("malformed decode entry (no logits output)"))?;
        // carry layout, validated once here: the feed wave indexes rows
        // by these strides every iteration, so a malformed entry fails
        // startup instead of a wave. `single_entry` is the per-session
        // view (stream_batch carry shapes minus the batch dim), used
        // for fresh-carry zeroing and import validation.
        let mut single_entry = stream_entry.clone();
        for idx in [1usize, 2] {
            let inp = single_entry
                .inputs
                .get_mut(idx)
                .ok_or_else(|| anyhow!("stream entry missing carry input {idx}"))?;
            if inp.shape.is_empty() {
                anyhow::bail!("stream entry carry input {idx} is scalar (no batch dim)");
            }
            inp.shape.remove(0);
        }
        let carry_input = |idx: usize| -> Result<(usize, Vec<usize>)> {
            let single = single_entry
                .inputs
                .get(idx)
                .ok_or_else(|| anyhow!("stream entry missing carry input {idx}"))?;
            let full = stream_entry
                .inputs
                .get(idx)
                .ok_or_else(|| anyhow!("stream entry missing carry input {idx}"))?;
            let stride = single.numel();
            if stride == 0 {
                anyhow::bail!("stream entry carry input {idx} has zero-sized rows");
            }
            Ok((stride, full.shape.clone()))
        };
        let (l_stride, shape_l) = carry_input(1)?;
        let (u_stride, shape_u) = carry_input(2)?;

        let queue = Arc::new(BoundedQueue::new(opts.queue_cap));
        let stats = Arc::new(ServerStats::default());
        // this server's instance metrics become the registry's live
        // view (`stlt stats` and Stats frames read the latest server)
        stats.publish();
        // per-node sigma/omega/T + half-life gauges: the paper's
        // interpretability story, sampled from the weights we serve
        #[cfg(feature = "native")]
        crate::runtime::native_stlt::publish_node_gauges(&stream_entry.config, &flat);
        let core = Arc::new(ServerCore {
            queue: Arc::clone(&queue),
            stats: Arc::clone(&stats),
            next_session: AtomicU64::new(1 << 32),
        });
        let stats_thread = Arc::clone(&stats);
        let max_sessions = opts.max_sessions;
        let backend = opts.backend;
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = thread::Builder::new()
            .name("stlt-model".into())
            .spawn(move || {
                let rt = match Runtime::new(backend) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                // pre-compile the executables before accepting traffic
                if let Err(e) = rt.load(&stream_entry).and_then(|_| rt.load(&decode_entry)) {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
                // batched continuous decode: derived entry, optional kind
                let batched = if rt.supports_kind("decode_batch") {
                    match stlt_exec::BatchedDecodeStep::from_decode(&decode_entry, b_srv)
                        .and_then(|b| rt.load(b.entry()).map(|_| b))
                    {
                        Ok(b) => Some(b),
                        Err(e) => {
                            crate::info!(
                                "server",
                                "decode_batch unavailable ({e:#}); per-row decode fallback"
                            );
                            None
                        }
                    }
                } else {
                    crate::info!(
                        "server",
                        "backend has no decode_batch kind; per-row decode fallback"
                    );
                    None
                };
                // upload the weights once (§Perf L3-1)
                let params = match stlt_exec::upload_params(&rt, &stream_entry, &flat) {
                    Ok(p) => p,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let _ = ready_tx.send(Ok(()));
                let mt = ModelThread {
                    rt,
                    params,
                    stream_entry,
                    decode_entry,
                    l_stride,
                    u_stride,
                    shape_l,
                    shape_u,
                    single_entry,
                    batched,
                    chunk,
                    b_srv,
                    vocab,
                    pool: StatePool::new(max_sessions),
                    stats: stats_thread,
                    feeds: Vec::new(),
                    gens: Vec::new(),
                    parked: VecDeque::new(),
                    scratch: WaveScratch::default(),
                };
                mt.run(&queue);
            })
            .expect("spawn model thread");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("model thread died during startup"))??;
        Ok(Server { core, stats, worker: Some(worker) })
    }

    /// Open a new session and return its handle. Ids are allocated from
    /// a range disjoint from hand-picked session-id-API ids.
    pub fn open_session(&self) -> SessionHandle {
        // ORDERING: Relaxed — the counter only needs uniqueness, not
        // ordering with any other memory; the id crosses threads inside
        // Request messages, which the channel itself orders.
        let id = self.core.next_session.fetch_add(1, Ordering::Relaxed);
        SessionHandle::new(id, Arc::clone(&self.core))
    }

    /// Stream a chunk of document tokens into a session. Blocking.
    /// (Session-id variant of [`SessionHandle::feed`].)
    pub fn feed(&self, session: u64, tokens: Vec<i32>, count_loss: bool) -> Result<FeedResult> {
        self.core.feed(session, tokens, count_loss)
    }

    /// Start a streamed generation on a session by id; returns the
    /// [`TokenStream`] immediately (see [`SessionHandle::generate`]).
    pub fn start_generate(&self, session: u64, opts: GenOpts) -> Result<TokenStream> {
        self.core.start_generate(session, opts)
    }

    /// Greedy generation continuing a session from `seed_token` (the
    /// last prompt token, which feed() leaves unconsumed). Blocking
    /// wrapper over the streamed path.
    pub fn generate(
        &self,
        session: u64,
        seed_token: i32,
        max_tokens: usize,
        stop: Option<i32>,
    ) -> Result<GenResult> {
        self.generate_with(session, seed_token, max_tokens, stop, Sampling::Greedy, 0)
    }

    /// Generation with an explicit sampling policy and RNG seed.
    /// Blocking wrapper: streams internally, returns the collected
    /// tokens once the generation finishes.
    pub fn generate_with(
        &self,
        session: u64,
        seed_token: i32,
        max_tokens: usize,
        stop: Option<i32>,
        sampling: Sampling,
        rng_seed: u64,
    ) -> Result<GenResult> {
        self.core
            .start_generate(session, GenOpts { seed_token, max_tokens, stop, sampling, rng_seed })?
            .wait()
    }

    /// Cancel a session's in-flight generation (session-id variant of
    /// [`SessionHandle::cancel`]).
    pub fn cancel(&self, session: u64) -> Result<()> {
        self.core.cancel(session)
    }

    pub fn release(&self, session: u64) -> Result<()> {
        self.core.release(session)
    }

    /// Export a session's carry by id (see
    /// [`SessionHandle::export_carry`]).
    pub fn export_carry(&self, session: u64) -> Result<CarrySnapshot> {
        self.core.export_carry(session)
    }

    /// Import a carry into a session by id (see
    /// [`SessionHandle::import_carry`]).
    pub fn import_carry(&self, session: u64, snap: CarrySnapshot) -> Result<Option<u64>> {
        self.core.import_carry(session, snap)
    }

    /// Handle over an explicit session id. The wire worker opens
    /// router-chosen ids with this so a session keeps its id across a
    /// migration — generation RNG is seeded `rng_seed ^ session`, so a
    /// preserved id is what keeps sampled continuations bitwise
    /// identical on the destination worker.
    pub(crate) fn session_handle(&self, id: u64) -> SessionHandle {
        SessionHandle::new(id, Arc::clone(&self.core))
    }

    pub fn shutdown(mut self) {
        self.core.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.core.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// One queued feed request inside a [`FeedTask`].
struct PendingFeed {
    tokens: Vec<i32>,
    count_loss: bool,
    resp: mpsc::Sender<Result<FeedResult>>,
    t0: Instant,
    /// Victim evicted when this feed admitted the session.
    evicted: Option<u64>,
    /// Input tokens consumed so far (the final token stays unconsumed).
    off: usize,
    nll: f64,
    cnt: f64,
}

/// A session with feed work in flight. Holds the session carry checked
/// out (pinned) for its whole lifetime, so interleaved admissions can
/// never evict a mid-feed session.
struct FeedTask {
    session: u64,
    carry: StreamCarry,
    queue: VecDeque<PendingFeed>,
    consumed_total: u64,
}

/// A generation in flight. Holds the carry checked out from admission
/// to finish; `carry == None` while parked behind an earlier feed on
/// the same session.
struct GenTask {
    session: u64,
    carry: Option<StreamCarry>,
    /// Next input token (seed_token, then each sampled token).
    token: i32,
    produced: usize,
    opts: GenOpts,
    rng: Rng,
    tx: mpsc::Sender<StreamItem>,
    t0: Instant,
    cancelled: bool,
}

/// Reusable per-wave scratch. The wave loops run for the server's
/// whole lifetime; everything here is allocated once and recycled so
/// the steady-state scheduler stays off the allocator (`stlt lint
/// --deep` enforces this — the tensor *inputs* still allocate because
/// the runtime takes them by value; see rust/lint_deep.allow).
#[derive(Default)]
struct WaveScratch {
    /// tokens consumed per feed-wave row
    consumed: Vec<usize>,
    /// sessions whose feed queues drained this wave
    drained: Vec<u64>,
    /// parked generations eligible for binding this decode wave
    unblocked: Vec<u64>,
    /// indices into `gens` of this decode wave's members
    wave_idx: Vec<usize>,
    /// last token per decode-wave row
    tokens: Vec<i32>,
    /// decode-wave members, moved out of `gens` for the step
    wave: Vec<GenTask>,
    /// decode-wave members that keep generating next wave
    survivors: Vec<GenTask>,
}

struct ModelThread {
    rt: Runtime,
    /// weights pre-uploaded as a device buffer (§Perf L3-1)
    params: stlt_exec::ParamBuf,
    stream_entry: Entry,
    decode_entry: Entry,
    /// per-row carry strides and full batched carry shapes of the
    /// stream entry, validated once at startup so the feed wave does
    /// no fallible entry-shape indexing per iteration
    l_stride: usize,
    u_stride: usize,
    shape_l: Vec<usize>,
    shape_u: Vec<usize>,
    /// per-session view of the stream entry (carry shapes minus the
    /// batch dim), prebuilt at startup
    single_entry: Entry,
    /// Batched continuous-decode executable; None on backends without
    /// the `decode_batch` kind (per-row fallback).
    batched: Option<stlt_exec::BatchedDecodeStep>,
    chunk: usize,
    b_srv: usize,
    /// Vocab size from the decode entry; seed tokens are validated
    /// against it at intake.
    vocab: usize,
    pool: StatePool,
    stats: Arc<ServerStats>,
    feeds: Vec<FeedTask>,
    gens: Vec<GenTask>,
    /// Requests that could not admit a session because every resident
    /// session was pinned by in-flight work (admission control):
    /// retried, in arrival order, at every scheduler iteration. A
    /// non-empty parked queue implies active tasks exist (only pinned
    /// sessions reject admission), so retries always ride on a working
    /// iteration — no spin, no deadlock.
    parked: VecDeque<(Request, Instant)>,
    scratch: WaveScratch,
}

/// Why a session's carry could not be acquired.
enum AcquireError {
    /// Every resident session is pinned by in-flight work — transient;
    /// the request parks until a wave frees a slot.
    Capacity,
    /// Permanent for this request (e.g. the carry is already checked
    /// out by a conflicting task).
    Other(anyhow::Error),
}

impl ModelThread {
    /// The continuous-batching scheduler loop. Each iteration: drain
    /// newly-arrived requests into the in-flight task sets (mid-flight
    /// admission), then run at most one feed wave and one decode wave
    /// (the fairness alternation). Blocks only when no work is in
    /// flight; exits when the queue is closed and everything drained.
    fn run(mut self, queue: &BoundedQueue<(Request, Instant)>) {
        loop {
            let mut incoming: Vec<(Request, Instant)> = Vec::new();
            if self.feeds.is_empty() && self.gens.is_empty() && self.parked.is_empty() {
                match queue.pop() {
                    Some(r) => incoming.push(r),
                    None => break, // closed and drained
                }
            }
            incoming.extend(queue.drain_up_to(INTAKE_MAX));
            // parked admissions retry first (arrival-order fairness),
            // then the new arrivals
            let mut retry: Vec<(Request, Instant)> = self.parked.drain(..).collect();
            retry.extend(incoming);
            for (req, t0) in retry {
                self.intake(req, t0);
            }
            if queue.is_closed() {
                // prompt shutdown: in-flight generations end Cancelled
                // at the next wave boundary instead of running out
                // their token budgets against a departing server
                for g in &mut self.gens {
                    g.cancelled = true;
                }
            }
            self.stats.park_depth.set(self.parked.len() as f64);
            if !self.feeds.is_empty() {
                self.feed_wave();
            }
            if !self.gens.is_empty() {
                self.decode_wave();
            }
        }
    }

    /// Finish `session`'s already-cancelled generations immediately, so
    /// a feed/generate submitted right after a cancel does not race the
    /// next wave boundary and get spuriously rejected as "in flight".
    fn reap_cancelled(&mut self, session: u64) {
        while let Some(pos) = self.gens.iter().position(|g| g.session == session && g.cancelled) {
            let g = self.gens.remove(pos);
            self.finish_gen(g, FinishReason::Cancelled);
        }
    }

    fn intake(&mut self, req: Request, t0: Instant) {
        let _span = crate::obs::span("scheduler", "intake");
        match req {
            Request::Feed { session, tokens, count_loss, resp } => {
                self.reap_cancelled(session);
                if self.gens.iter().any(|g| g.session == session) {
                    let _ = resp.send(Err(anyhow!(
                        "session {session}: a generation is in flight; cancel it or \
                         wait for its stream to finish before feeding"
                    )));
                    return;
                }
                if let Some(ft) = self.feeds.iter_mut().find(|f| f.session == session) {
                    ft.queue.push_back(PendingFeed {
                        tokens,
                        count_loss,
                        resp,
                        t0,
                        evicted: None,
                        off: 0,
                        nll: 0.0,
                        cnt: 0.0,
                    });
                    return;
                }
                match self.acquire(session) {
                    Ok((carry, evicted, _fresh)) => {
                        let mut q = VecDeque::new();
                        q.push_back(PendingFeed {
                            tokens,
                            count_loss,
                            resp,
                            t0,
                            evicted,
                            off: 0,
                            nll: 0.0,
                            cnt: 0.0,
                        });
                        self.feeds.push(FeedTask { session, carry, queue: q, consumed_total: 0 });
                    }
                    Err(AcquireError::Capacity) => {
                        let req = Request::Feed { session, tokens, count_loss, resp };
                        self.stats.parked_total.inc();
                        self.parked.push_back((req, t0));
                    }
                    Err(AcquireError::Other(e)) => {
                        let _ = resp.send(Err(e));
                    }
                }
            }
            Request::Generate { session, opts, tx } => {
                self.reap_cancelled(session);
                if self.gens.iter().any(|g| g.session == session) {
                    let _ = tx.send(StreamItem::End(Err(anyhow!(
                        "session {session}: a generation is already in flight"
                    ))));
                    return;
                }
                // validate the seed token here so one client's bad
                // request can never abort a whole batched decode wave
                // of innocent sessions (sampled tokens are in-vocab by
                // construction, so this is the only entry point)
                if opts.seed_token < 0 || opts.seed_token as usize >= self.vocab {
                    let _ = tx.send(StreamItem::End(Err(anyhow!(
                        "seed_token {} out of vocab {}",
                        opts.seed_token,
                        self.vocab
                    ))));
                    return;
                }
                let behind_feed = self.feeds.iter().any(|f| f.session == session);
                let mut bound = None;
                if !behind_feed {
                    match self.acquire(session) {
                        Ok(acq) => bound = Some(acq),
                        Err(AcquireError::Capacity) => {
                            self.stats.parked_total.inc();
                            self.parked.push_back((Request::Generate { session, opts, tx }, t0));
                            return;
                        }
                        Err(AcquireError::Other(e)) => {
                            let _ = tx.send(StreamItem::End(Err(e)));
                            return;
                        }
                    }
                }
                let rng = Rng::new(opts.rng_seed ^ session);
                let mut task = GenTask {
                    session,
                    carry: None,
                    token: opts.seed_token,
                    produced: 0,
                    opts,
                    rng,
                    tx,
                    t0,
                    cancelled: false,
                };
                if let Some((carry, evicted, fresh)) = bound {
                    task.carry = Some(carry);
                    let _ = task.tx.send(StreamItem::Start { evicted, fresh_carry: fresh });
                }
                // without a bound carry the task parks behind the
                // session's feed queue; it is bound when that drains
                self.gens.push(task);
            }
            Request::Cancel { session } => {
                for g in self.gens.iter_mut().filter(|g| g.session == session) {
                    g.cancelled = true;
                }
                // a capacity-parked generation cancels before it starts
                self.drop_parked(session, false);
            }
            Request::Release { session } => {
                if let Some(pos) = self.feeds.iter().position(|f| f.session == session) {
                    let ft = self.feeds.remove(pos);
                    for p in ft.queue {
                        let _ = p.resp.send(Err(anyhow!("session {session} released mid-feed")));
                    }
                }
                if let Some(pos) = self.gens.iter().position(|g| g.session == session) {
                    let g = self.gens.remove(pos);
                    self.finish_gen(g, FinishReason::Cancelled);
                }
                self.drop_parked(session, true);
                self.pool.release(session);
            }
            Request::ExportCarry { session, resp } => {
                self.reap_cancelled(session);
                let _ = resp.send(self.export_snapshot(session));
            }
            Request::ImportCarry { session, snap, resp } => {
                self.reap_cancelled(session);
                if self.feeds.iter().any(|f| f.session == session)
                    || self.gens.iter().any(|g| g.session == session)
                {
                    let _ = resp.send(Err(anyhow!(
                        "session {session}: cannot import a carry while a feed or \
                         generation is in flight"
                    )));
                    return;
                }
                // validate against this server's model before touching
                // the pool: a snapshot from a different model geometry
                // must fail loudly, not corrupt a wave later
                let (l_stride, u_stride) = (self.l_stride, self.u_stride);
                if snap.l.len() != l_stride || snap.u.len() != u_stride {
                    let _ = resp.send(Err(anyhow!(
                        "carry shape mismatch: snapshot is ({}, {}) f32s, this model wants \
                         ({l_stride}, {u_stride}) — importing across different models?",
                        snap.l.len(),
                        snap.u.len()
                    )));
                    return;
                }
                // adopt the server's own canonical shapes (numel-equal
                // reshapes in a foreign snapshot must not leak in)
                let carry = StreamCarry {
                    l: snap.l,
                    u: snap.u,
                    l_shape: self.single_entry.inputs[1].shape.clone(),
                    u_shape: self.single_entry.inputs[2].shape.clone(),
                };
                match self.pool.import(session, carry, snap.tokens_seen) {
                    Import::Ok => {
                        let _ = resp.send(Ok(None));
                    }
                    Import::Evicted(v) => {
                        self.stats.evictions.inc();
                        let _ = resp.send(Ok(Some(v)));
                    }
                    Import::InFlight(_) => {
                        // unreachable given the task-set check above,
                        // but keep the refusal honest if it ever races
                        let _ = resp.send(Err(anyhow!(
                            "session {session}: carry is checked out by in-flight work"
                        )));
                    }
                    Import::NoCapacity(carry) => {
                        // park-and-retry like feed/generate admission:
                        // every resident session is pinned, so a wave
                        // is in flight and will free a slot
                        let snap = CarrySnapshot {
                            l: carry.l,
                            u: carry.u,
                            l_shape: carry.l_shape,
                            u_shape: carry.u_shape,
                            tokens_seen: snap.tokens_seen,
                        };
                        self.stats.parked_total.inc();
                        self.parked.push_back((Request::ImportCarry { session, snap, resp }, t0));
                    }
                }
            }
        }
    }

    /// Export a session's carry as a [`CarrySnapshot`], mapping pool
    /// outcomes to client-facing errors.
    fn export_snapshot(&self, session: u64) -> Result<CarrySnapshot> {
        match self.pool.export(session) {
            Export::Missing => Err(anyhow!(
                "session {session}: no resident state to export (never fed, or evicted)"
            )),
            Export::InFlight => Err(anyhow!(
                "session {session}: cannot export while a feed or generation holds the carry"
            )),
            Export::Carry { carry, tokens_seen } => Ok(CarrySnapshot {
                l: carry.l,
                u: carry.u,
                l_shape: carry.l_shape,
                u_shape: carry.u_shape,
                tokens_seen,
            }),
        }
    }

    /// Resolve `session`'s capacity-parked requests on cancel/release:
    /// parked generations end Cancelled; parked feeds (only when
    /// `feeds_too`, i.e. release) fail with a clear error.
    fn drop_parked(&mut self, session: u64, feeds_too: bool) {
        let mut kept = VecDeque::new();
        for (req, t0) in self.parked.drain(..) {
            match req {
                Request::Generate { session: s, tx, .. } if s == session => {
                    self.stats.cancelled.inc();
                    let _ = tx.send(StreamItem::End(Ok(FinishReason::Cancelled)));
                }
                Request::Feed { session: s, resp, .. } if feeds_too && s == session => {
                    let _ = resp.send(Err(anyhow!("session {session} released before its \
                         feed could be admitted")));
                }
                Request::ImportCarry { session: s, resp, .. } if feeds_too && s == session => {
                    let _ = resp.send(Err(anyhow!("session {session} released before its \
                         carry import could be admitted")));
                }
                other => kept.push_back((other, t0)),
            }
        }
        self.parked = kept;
    }

    /// Admit (if needed) and check out a session's carry. Returns
    /// (carry, evicted victim, fresh-carry flag).
    fn acquire(
        &mut self,
        session: u64,
    ) -> std::result::Result<(StreamCarry, Option<u64>, bool), AcquireError> {
        let fresh = !self.pool.contains(session);
        let mut evicted = None;
        if fresh {
            let carry = StreamCarry::zeros(&self.single_entry);
            match self.pool.admit(session, carry) {
                Admit::Evicted(v) => {
                    self.stats.evictions.inc();
                    evicted = Some(v);
                }
                Admit::Rejected => return Err(AcquireError::Capacity),
                Admit::Ok => {}
            }
        }
        let carry = self.pool.checkout(session).ok_or_else(|| {
            AcquireError::Other(anyhow!("session {session}: state is already in flight"))
        })?;
        Ok((carry, evicted, fresh))
    }

    /// Bind a parked generation once `session`'s feed queue has
    /// drained (or fail its stream if the state is gone).
    fn activate_waiting_gen(&mut self, session: u64) {
        let parked = self.gens.iter().position(|g| g.session == session && g.carry.is_none());
        let pos = match parked {
            Some(p) => p,
            None => return,
        };
        match self.acquire(session) {
            Ok((carry, evicted, fresh)) => {
                // `pos` came from `position` on this same vec, so the
                // lookup cannot miss; a None here would only mean the
                // task vanished, in which case the carry returns to the
                // pool at the next checkin
                if let Some(g) = self.gens.get_mut(pos) {
                    g.carry = Some(carry);
                    let _ = g.tx.send(StreamItem::Start { evicted, fresh_carry: fresh });
                }
            }
            // Capacity here is transient (the feed that just drained
            // released a slot another admission raced onto): leave the
            // task parked; decode_wave retries binding every iteration.
            Err(AcquireError::Capacity) => {}
            Err(AcquireError::Other(e)) => {
                let g = self.gens.remove(pos);
                let _ = g.tx.send(StreamItem::End(Err(e)));
            }
        }
    }

    /// One feed wave: advance up to b_srv feeding sessions by ONE chunk
    /// each through the `stream_batch` artifact, then rotate them
    /// behind any sessions that did not make this wave.
    ///
    /// F64-REDUCE: per-pending NLL/count totals accumulate in f64
    /// (`p.nll`, `p.cnt`) so chunking never moves the reported loss.
    fn feed_wave(&mut self) {
        let _span = crate::obs::span("scheduler", "feed_wave");
        let b = self.b_srv;
        let c = self.chunk;
        let wave = self.feeds.len().min(b);
        let (l_stride, u_stride) = (self.l_stride, self.u_stride);
        // the tensor inputs below are moved into the runtime by value,
        // so they allocate per wave (see rust/lint_deep.allow); the
        // bookkeeping vectors are recycled through `self.scratch`
        let mut l_all = Vec::with_capacity(b * l_stride);
        let mut u_all = Vec::with_capacity(b * u_stride);
        let mut toks = vec![0i32; b * c];
        let mut tgts = vec![0i32; b * c];
        let mut mask = vec![0f32; b * c];
        let mut active = vec![0f32; b];
        self.scratch.consumed.clear();
        self.scratch.consumed.resize(wave, 0);
        let mut any = false;
        for ((((ft, cons), tok_row), tgt_row), (mask_row, act)) in self
            .feeds
            .iter()
            .take(wave)
            .zip(self.scratch.consumed.iter_mut())
            .zip(toks.chunks_exact_mut(c))
            .zip(tgts.chunks_exact_mut(c))
            .zip(mask.chunks_exact_mut(c).zip(active.iter_mut()))
        {
            // intake never admits a task with an empty queue; a row
            // that somehow lost its pending rides as inactive (its
            // carry must still occupy the row so later rows stay
            // aligned with their strided slots)
            if let Some(p) = ft.queue.front() {
                let remaining = p.tokens.len().saturating_sub(p.off);
                if remaining > 1 {
                    let take = remaining.min(c + 1); // need next-token targets
                    // PANIC-OK: off <= tokens.len() and take <= remaining
                    // = tokens.len() - off, by the arithmetic above
                    let src = &p.tokens[p.off..p.off + take];
                    let n_in = take - 1;
                    let loss = if p.count_loss { 1.0 } else { 0.0 };
                    // (token, next-token) pairs; the zip is bounded by
                    // the row width c >= n_in since take <= c + 1
                    for (((dst_t, dst_g), dst_m), (cur, nxt)) in tok_row
                        .iter_mut()
                        .zip(tgt_row.iter_mut())
                        .zip(mask_row.iter_mut())
                        .zip(src.iter().zip(src.iter().skip(1)))
                    {
                        *dst_t = *cur;
                        *dst_g = *nxt;
                        *dst_m = loss;
                    }
                    *act = 1.0;
                    *cons = n_in;
                    any = true;
                }
            }
            l_all.extend_from_slice(&ft.carry.l);
            u_all.extend_from_slice(&ft.carry.u);
        }
        // pad the remaining rows with zero carries
        l_all.resize(b * l_stride, 0.0);
        u_all.resize(b * u_stride, 0.0);
        if any {
            let fill = self.scratch.consumed.iter().filter(|&&x| x > 0).count();
            self.stats.record_wave(fill);
            let e = &self.stream_entry;
            let out = self.rt.run_with_param_buffer(
                e,
                self.params.buffer(),
                &[
                    Tensor::f32(l_all, &self.shape_l),
                    Tensor::f32(u_all, &self.shape_u),
                    Tensor::i32(toks, &[b, c]),
                    Tensor::i32(tgts, &[b, c]),
                    Tensor::f32(mask, &[b, c]),
                    Tensor::f32(active, &[b]),
                ],
            );
            let parsed =
                out.and_then(|o| Self::parse_stream_batch_out(o, b, l_stride, u_stride));
            let (l_new, u_new, nll, cnt) = match parsed {
                Ok(t) => t,
                Err(err) => {
                    let msg = format!("{err:#}");
                    self.fail_feed_wave(wave, &msg);
                    return;
                }
            };
            // scatter the step's outputs back row by row; every zip is
            // bounded by parse_stream_batch_out's size check, so no row
            // access here can go out of range
            for ((ft, cons), ((l_row, u_row), (nll_i, cnt_i))) in self
                .feeds
                .iter_mut()
                .take(wave)
                .zip(self.scratch.consumed.iter())
                .zip(
                    l_new
                        .chunks_exact(l_stride)
                        .zip(u_new.chunks_exact(u_stride))
                        .zip(nll.iter().zip(cnt.iter())),
                )
            {
                if *cons == 0 {
                    continue;
                }
                ft.carry.l.clear();
                ft.carry.l.extend_from_slice(l_row);
                ft.carry.u.clear();
                ft.carry.u.extend_from_slice(u_row);
                let p = match ft.queue.front_mut() {
                    Some(p) => p,
                    None => continue,
                };
                p.nll += f64::from(*nll_i);
                p.cnt += f64::from(*cnt_i);
                p.off += *cons;
                self.stats.tokens_streamed.add(*cons as u64);
            }
        }
        // completion sweep (reverse so removals keep indices valid):
        // finished pendings respond; tasks with drained queues check
        // their carry back in and unpark any waiting generation
        let mut removed = 0usize;
        self.scratch.drained.clear();
        for i in (0..wave).rev() {
            let ft = match self.feeds.get_mut(i) {
                Some(ft) => ft,
                None => continue,
            };
            let done = match ft.queue.front() {
                Some(p) => p.tokens.len().saturating_sub(p.off) <= 1,
                None => true,
            };
            if !done {
                continue;
            }
            if let Some(p) = ft.queue.pop_front() {
                ft.consumed_total += p.off as u64;
                self.stats.feeds.inc();
                self.stats.feed_latency.record(p.t0.elapsed().as_secs_f64());
                let fr = FeedResult { nll_sum: p.nll, count: p.cnt, evicted: p.evicted };
                let _ = p.resp.send(Ok(fr));
            }
            if ft.queue.is_empty() {
                let ft = self.feeds.remove(i);
                self.pool.checkin(ft.session, ft.carry, ft.consumed_total);
                self.scratch.drained.push(ft.session);
                removed += 1;
            }
        }
        // fairness rotation: surviving wave members go to the back
        let still = wave - removed;
        if still > 0 && self.feeds.len() > still {
            self.feeds.rotate_left(still);
        }
        // (take/restore: activate_waiting_gen needs &mut self)
        let mut drained = std::mem::take(&mut self.scratch.drained);
        for s in drained.drain(..) {
            self.activate_waiting_gen(s);
        }
        self.scratch.drained = drained;
    }

    /// Parse (l', u', nll [b], count [b]) from a stream_batch output
    /// set. Arity/shape mismatches surface as errors — not indexing
    /// panics past the failure path — so a malformed backend output
    /// fails only the wave (PR-4's pop_out hardening, applied to the
    /// one remaining indexed-unwrap parse).
    fn parse_stream_batch_out(
        mut out: Vec<Tensor>,
        b: usize,
        l_stride: usize,
        u_stride: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mut pop = |what: &str| -> Result<Vec<f32>> {
            out.pop()
                .ok_or_else(|| anyhow!("stream_batch returned too few outputs: missing {what}"))?
                .into_f32()
        };
        let cnt = pop("count")?;
        let nll = pop("nll")?;
        let u = pop("u")?;
        let l = pop("l")?;
        if l.len() != b * l_stride || u.len() != b * u_stride || nll.len() < b || cnt.len() < b {
            anyhow::bail!(
                "stream_batch output sizes (l {}, u {}, nll {}, count {}) do not match b={b}",
                l.len(),
                u.len(),
                nll.len(),
                cnt.len()
            );
        }
        Ok((l, u, nll, cnt))
    }

    /// Fail every pending feed of the current wave's tasks and drop
    /// their sessions (their carries are mid-step; a clean re-feed is
    /// the recovery path, as with the old whole-wave semantics).
    fn fail_feed_wave(&mut self, wave: usize, msg: &str) {
        let failed: Vec<FeedTask> = self.feeds.drain(..wave).collect();
        for ft in failed {
            for p in ft.queue {
                let _ = p.resp.send(Err(anyhow!("stream step failed: {msg}")));
            }
            self.pool.release(ft.session);
            // a generation parked behind this feed cannot proceed
            // meaningfully; fail its stream too
            let parked =
                self.gens.iter().position(|g| g.session == ft.session && g.carry.is_none());
            if let Some(pos) = parked {
                let g = self.gens.remove(pos);
                let _ = g.tx.send(StreamItem::End(Err(anyhow!(
                    "session {}: feed failed before generation started: {msg}",
                    ft.session
                ))));
            }
        }
    }

    /// One decode wave: advance up to b_srv ready generations by ONE
    /// token each — batched through `decode_batch` where the backend
    /// supports it, per-row otherwise — then rotate survivors behind
    /// waiting sessions so every generation makes progress.
    fn decode_wave(&mut self) {
        let _span = crate::obs::span("scheduler", "decode_wave");
        // cancelled (or zero-budget) tasks finish at the wave boundary
        let mut i = 0;
        while i < self.gens.len() {
            let (cancelled, exhausted) = match self.gens.get(i) {
                Some(g) => (g.cancelled, g.produced >= g.opts.max_tokens),
                None => break,
            };
            if cancelled {
                let g = self.gens.remove(i);
                self.finish_gen(g, FinishReason::Cancelled);
            } else if exhausted {
                let g = self.gens.remove(i);
                self.finish_gen(g, FinishReason::MaxTokens);
            } else {
                i += 1;
            }
        }
        // bind any generation still parked without a feed in front of
        // it (covers the rare admission race on activation, and makes
        // a parked task never depend on a future request to progress)
        self.scratch.unblocked.clear();
        let feeds = &self.feeds;
        self.scratch.unblocked.extend(
            self.gens
                .iter()
                .filter(|g| g.carry.is_none())
                .map(|g| g.session)
                .filter(|s| !feeds.iter().any(|f| f.session == *s)),
        );
        let mut unblocked = std::mem::take(&mut self.scratch.unblocked);
        for &s in unblocked.iter() {
            self.activate_waiting_gen(s);
        }
        self.scratch.unblocked = unblocked;
        // wave = the first b_srv tasks whose carry is bound
        self.scratch.wave_idx.clear();
        for (i, g) in self.gens.iter().enumerate() {
            if g.carry.is_some() {
                self.scratch.wave_idx.push(i);
                if self.scratch.wave_idx.len() == self.b_srv {
                    break;
                }
            }
        }
        if self.scratch.wave_idx.is_empty() {
            return;
        }
        self.stats.record_wave(self.scratch.wave_idx.len());
        let mut wave = std::mem::take(&mut self.scratch.wave);
        wave.clear();
        for &i in self.scratch.wave_idx.iter().rev() {
            wave.push(self.gens.remove(i));
        }
        wave.reverse();
        let mut tokens = std::mem::take(&mut self.scratch.tokens);
        tokens.clear();
        tokens.extend(wave.iter().map(|g| g.token));
        // single-row waves take the plain decode_step (no batch padding
        // to gather for one session); multi-row waves are the batched
        // continuous-decode hot path. The two are bitwise identical per
        // row (the decode_batch parity seam), so wave size never leaks
        // into outputs. Outcomes are per row: a failed row ends only
        // its own stream — and on any failure the affected carries are
        // left exactly as they were (run_h gathers by copy; the
        // per-row path only assigns after a fully parsed output), so a
        // failed step never silently consumes a token.
        let results: Vec<Result<Vec<f32>>> = match &self.batched {
            Some(batch) if wave.len() > 1 => {
                let mut carries: Vec<&mut StreamCarry> = wave
                    .iter_mut()
                    .map(|g| g.carry.as_mut().expect("wave task has carry"))
                    .collect();
                match batch.run_h(&self.rt, &self.params, &mut carries, &tokens) {
                    Ok(rows) => rows.into_iter().map(Ok).collect(),
                    Err(e) => {
                        let msg = format!("{e:#}");
                        (0..wave.len())
                            .map(|_| Err(anyhow!("decode step failed: {msg}")))
                            .collect()
                    }
                }
            }
            _ => self.decode_rows_sequential(&mut wave, &tokens),
        };
        let mut survivors = std::mem::take(&mut self.scratch.survivors);
        survivors.clear();
        for (mut g, res) in wave.drain(..).zip(results) {
            let logits = match res {
                Ok(l) => l,
                Err(e) => {
                    self.finish_gen_err(g, e);
                    continue;
                }
            };
            let tok = g.opts.sampling.sample(&logits, &mut g.rng) as i32;
            g.token = tok;
            g.produced += 1;
            if g.produced == 1 {
                self.stats.ttft_latency.record(g.t0.elapsed().as_secs_f64());
            }
            self.stats.tokens_generated.inc();
            if g.tx.send(StreamItem::Token(tok)).is_err() {
                // client dropped the stream: implicit cancel
                self.finish_gen(g, FinishReason::Cancelled);
            } else if Some(tok) == g.opts.stop {
                self.finish_gen(g, FinishReason::Stop);
            } else if g.produced >= g.opts.max_tokens {
                self.finish_gen(g, FinishReason::MaxTokens);
            } else {
                survivors.push(g);
            }
        }
        // fairness rotation: survivors rejoin at the back
        self.gens.extend(survivors.drain(..));
        self.scratch.survivors = survivors;
        self.scratch.tokens = tokens;
        self.scratch.wave = wave;
    }

    /// Per-row decode fallback for backends without the `decode_batch`
    /// kind (e.g. XLA, whose programs are AOT-lowered per entry) and
    /// for single-row waves. Each row gets its own outcome through
    /// [`stlt_exec::DecodeStep::run_h`] — the same zero-copy
    /// take-and-restore hot path as standalone decoding, so a failed
    /// row's carry is left intact and sibling rows are unaffected.
    fn decode_rows_sequential(
        &self,
        wave: &mut [GenTask],
        tokens: &[i32],
    ) -> Vec<Result<Vec<f32>>> {
        let step = match stlt_exec::DecodeStep::from_entry(&self.rt, &self.decode_entry) {
            Ok(s) => s,
            Err(e) => {
                let msg = format!("{e:#}");
                return wave.iter().map(|_| Err(anyhow!("{msg}"))).collect();
            }
        };
        let mut rows = Vec::with_capacity(wave.len());
        for (g, &tok) in wave.iter_mut().zip(tokens) {
            let carry = g.carry.as_mut().expect("wave task has carry");
            rows.push(step.run_h(&self.params, carry, tok));
        }
        rows
    }

    /// End a generation: return the carry to the pool, record stats,
    /// and close the stream with `reason`.
    fn finish_gen(&mut self, g: GenTask, reason: FinishReason) {
        if let Some(carry) = g.carry {
            self.pool.checkin(g.session, carry, g.produced as u64);
        }
        self.stats.gens.inc();
        self.stats.gen_latency.record(g.t0.elapsed().as_secs_f64());
        if reason == FinishReason::Cancelled {
            self.stats.cancelled.inc();
        }
        let _ = g.tx.send(StreamItem::End(Ok(reason)));
    }

    /// End a generation with a model-thread error; the carry (restored
    /// by the exec layer) returns to the pool so the session survives.
    fn finish_gen_err(&mut self, g: GenTask, err: anyhow::Error) {
        if let Some(carry) = g.carry {
            self.pool.checkin(g.session, carry, g.produced as u64);
        }
        self.stats.gens.inc();
        // errored generations stay in the latency histogram (they are
        // often the slowest ones; dropping them would read optimistic)
        self.stats.gen_latency.record(g.t0.elapsed().as_secs_f64());
        let _ = g.tx.send(StreamItem::End(Err(err)));
    }
}
