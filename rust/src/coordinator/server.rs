//! Streaming inference server: the L3 coordination contribution.
//!
//! Architecture (vLLM-router-shaped, adapted to STLT's O(S d) carries):
//!
//!   clients --> BoundedQueue (admission control / backpressure)
//!            --> Batcher (deadline-based dynamic batching)
//!            --> model thread (single PJRT owner)
//!                 * Feed chunks: packed into the `stream_batch`
//!                   artifact, padded with inactive rows
//!                 * Generate: token-by-token via `decode_step`
//!            --> per-request response channels
//!
//! Session carries live in the StatePool ("KV-cache analog"): admitting
//! beyond capacity LRU-evicts an idle session. All latencies are
//! recorded in log-bucket histograms.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::metrics::Histogram;
use crate::runtime::artifact::Entry;
use crate::runtime::exec as stlt_exec;
use crate::runtime::{BackendKind, Manifest, Runtime, StreamCarry, Tensor};

// Backend device handles may be !Send (xla's PJRT wraps Rc + raw
// pointers), so the model thread constructs its own Runtime and is the
// only thread to touch it; everything crossing the thread boundary is
// plain data (BackendKind is Copy + Send).

use super::batcher::{BatchPolicy, Batcher};
use super::sampling::Sampling;
use super::queue::{BoundedQueue, PushError};
use super::state::{Admit, StatePool};

pub struct ServerOpts {
    pub queue_cap: usize,
    pub max_sessions: usize,
    pub policy: BatchPolicy,
    /// Execution substrate for the model thread (default: native).
    pub backend: BackendKind,
}

impl Default for ServerOpts {
    fn default() -> Self {
        ServerOpts {
            queue_cap: 64,
            max_sessions: 16,
            policy: BatchPolicy::default(),
            backend: BackendKind::default(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct FeedResult {
    pub nll_sum: f64,
    pub count: f64,
    pub evicted: Option<u64>,
}

#[derive(Clone, Debug)]
pub struct GenResult {
    pub tokens: Vec<i32>,
}

enum Request {
    Feed { session: u64, tokens: Vec<i32>, count_loss: bool, resp: mpsc::Sender<Result<FeedResult>> },
    Generate { session: u64, seed_token: i32, max_tokens: usize, stop: Option<i32>, sampling: Sampling, rng_seed: u64, resp: mpsc::Sender<Result<GenResult>> },
    Release { session: u64 },
}

#[derive(Default)]
pub struct ServerStats {
    pub feeds: AtomicU64,
    pub gens: AtomicU64,
    pub evictions: AtomicU64,
    pub shed: AtomicU64,
    pub tokens_streamed: AtomicU64,
    pub batch_fill: Mutex<Vec<usize>>,
    pub feed_latency: Mutex<Histogram>,
    pub gen_latency: Mutex<Histogram>,
}

pub struct Server {
    queue: Arc<BoundedQueue<(Request, Instant)>>,
    pub stats: Arc<ServerStats>,
    worker: Option<thread::JoinHandle<()>>,
}

struct ModelThread {
    rt: Runtime,
    /// weights pre-uploaded as a PJRT buffer (§Perf L3-1): no per-call copy
    params: stlt_exec::ParamBuf,
    stream_entry: Entry,
    decode_entry: Entry,
    chunk: usize,
    b_srv: usize,
    pool: StatePool,
    stats: Arc<ServerStats>,
}

impl Server {
    /// `artifact_base` e.g. "lm_stlt_tiny"; `flat` the trained params.
    /// The runtime is created *inside* the model thread (backend device
    /// handles may be !Send); start() blocks until both executables are
    /// loaded (compiled, on the xla backend).
    pub fn start(
        manifest: &Manifest,
        artifact_base: &str,
        flat: Vec<f32>,
        opts: ServerOpts,
    ) -> Result<Server> {
        let stream_entry = manifest.get(&format!("{artifact_base}.stream_batch"))?.clone();
        let decode_entry = manifest.get(&format!("{artifact_base}.decode"))?.clone();
        let chunk = *stream_entry.extra.get("chunk").ok_or_else(|| anyhow!("no chunk"))? as usize;
        let b_srv =
            *stream_entry.extra.get("batch_srv").ok_or_else(|| anyhow!("no batch_srv"))? as usize;

        let queue = Arc::new(BoundedQueue::new(opts.queue_cap));
        let stats = Arc::new(ServerStats::default());
        let batcher = Batcher::new(Arc::clone(&queue), opts.policy.clone());
        let stats_thread = Arc::clone(&stats);
        let max_sessions = opts.max_sessions;
        let backend = opts.backend;
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = thread::Builder::new()
            .name("stlt-model".into())
            .spawn(move || {
                let rt = match Runtime::new(backend) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                // pre-compile both executables before accepting traffic
                if let Err(e) = rt.load(&stream_entry).and_then(|_| rt.load(&decode_entry)) {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
                // upload the weights once (§Perf L3-1)
                let params = match stlt_exec::upload_params(&rt, &stream_entry, &flat) {
                    Ok(p) => p,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let _ = ready_tx.send(Ok(()));
                let mut mt = ModelThread {
                    rt,
                    params,
                    stream_entry,
                    decode_entry,
                    chunk,
                    b_srv,
                    pool: StatePool::new(max_sessions),
                    stats: stats_thread,
                };
                while let Some(batch) = batcher.next_batch() {
                    mt.process(batch);
                }
            })
            .expect("spawn model thread");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("model thread died during startup"))??;
        Ok(Server { queue, stats, worker: Some(worker) })
    }

    fn submit(&self, req: Request) -> Result<()> {
        match self.queue.push((req, Instant::now()), Duration::from_secs(30)) {
            Ok(()) => Ok(()),
            Err(PushError::Timeout) => {
                self.stats.shed.fetch_add(1, Ordering::Relaxed);
                Err(anyhow!("server overloaded (backpressure timeout)"))
            }
            Err(PushError::Closed) => Err(anyhow!("server shut down")),
        }
    }

    /// Stream a chunk of document tokens into a session. Blocking.
    pub fn feed(&self, session: u64, tokens: Vec<i32>, count_loss: bool) -> Result<FeedResult> {
        let (tx, rx) = mpsc::channel();
        self.submit(Request::Feed { session, tokens, count_loss, resp: tx })?;
        rx.recv().map_err(|_| anyhow!("model thread dropped request"))?
    }

    /// Greedy generation continuing a session from `seed_token` (the
    /// last prompt token, which feed() leaves unconsumed). Blocking.
    pub fn generate(
        &self,
        session: u64,
        seed_token: i32,
        max_tokens: usize,
        stop: Option<i32>,
    ) -> Result<GenResult> {
        self.generate_with(session, seed_token, max_tokens, stop, Sampling::Greedy, 0)
    }

    /// Generation with an explicit sampling policy (temperature / top-k /
    /// nucleus) and RNG seed for reproducible stochastic decoding.
    pub fn generate_with(
        &self,
        session: u64,
        seed_token: i32,
        max_tokens: usize,
        stop: Option<i32>,
        sampling: Sampling,
        rng_seed: u64,
    ) -> Result<GenResult> {
        let (tx, rx) = mpsc::channel();
        self.submit(Request::Generate {
            session, seed_token, max_tokens, stop, sampling, rng_seed, resp: tx,
        })?;
        rx.recv().map_err(|_| anyhow!("model thread dropped request"))?
    }

    pub fn release(&self, session: u64) -> Result<()> {
        self.submit(Request::Release { session })
    }

    pub fn shutdown(mut self) {
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl ModelThread {
    fn process(&mut self, batch: Vec<(Request, Instant)>) {
        let mut feeds = Vec::new();
        for (req, t0) in batch {
            match req {
                Request::Feed { session, tokens, count_loss, resp } => {
                    feeds.push((session, tokens, count_loss, resp, t0));
                }
                Request::Generate { session, seed_token, max_tokens, stop, sampling, rng_seed, resp } => {
                    let r = self.run_generate(session, seed_token, max_tokens, stop, sampling, rng_seed);
                    self.stats.gens.fetch_add(1, Ordering::Relaxed);
                    self.stats.gen_latency.lock().unwrap().record(t0.elapsed().as_secs_f64());
                    let _ = resp.send(r);
                }
                Request::Release { session } => {
                    self.pool.release(session);
                }
            }
        }
        // process feeds in waves of b_srv sessions
        while !feeds.is_empty() {
            let wave: Vec<_> = feeds.drain(..feeds.len().min(self.b_srv)).collect();
            self.run_feed_wave(wave);
        }
    }

    fn admit_session(&mut self, session: u64) -> Option<u64> {
        if self.pool.contains(session) {
            return None;
        }
        let carry = StreamCarry::zeros(&self.stream_entry_single());
        match self.pool.admit(session, carry) {
            Admit::Evicted(v) => {
                self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            _ => None,
        }
    }

    /// Per-session carry shapes = stream_batch shapes minus batch dim.
    fn stream_entry_single(&self) -> Entry {
        let mut e = self.stream_entry.clone();
        e.inputs[1].shape = self.stream_entry.inputs[1].shape[1..].to_vec();
        e.inputs[2].shape = self.stream_entry.inputs[2].shape[1..].to_vec();
        e
    }

    /// One wave: up to b_srv sessions, each feeding up to `chunk` tokens
    /// per model call, iterating until every session's tokens are drained.
    fn run_feed_wave(
        &mut self,
        wave: Vec<(u64, Vec<i32>, bool, mpsc::Sender<Result<FeedResult>>, Instant)>,
    ) {
        let b = self.b_srv;
        let c = self.chunk;
        let mut sessions = Vec::new();
        for (session, tokens, count_loss, resp, t0) in wave {
            let evicted = self.admit_session(session);
            sessions.push((session, tokens, count_loss, resp, t0, evicted, 0.0f64, 0.0f64, 0usize));
        }
        self.stats.batch_fill.lock().unwrap().push(sessions.len());
        loop {
            // build one batched chunk step
            let mut any = false;
            let mut l_all = Vec::new();
            let mut u_all = Vec::new();
            let mut toks = vec![0i32; b * c];
            let mut tgts = vec![0i32; b * c];
            let mut mask = vec![0f32; b * c];
            let mut active = vec![0f32; b];
            let mut carries: Vec<Option<StreamCarry>> = Vec::with_capacity(b);
            let mut consumed = vec![0usize; sessions.len()];
            for (i, (session, tokens, count_loss, _, _, _, _, _, off)) in
                sessions.iter().enumerate()
            {
                if i >= b {
                    break;
                }
                let remaining = tokens.len().saturating_sub(*off);
                if remaining <= 1 {
                    carries.push(None);
                    continue;
                }
                let take = remaining.min(c + 1); // need next-token targets
                let slice = &tokens[*off..*off + take];
                let n_in = take - 1;
                for j in 0..n_in {
                    toks[i * c + j] = slice[j];
                    tgts[i * c + j] = slice[j + 1];
                    mask[i * c + j] = if *count_loss { 1.0 } else { 0.0 };
                }
                active[i] = 1.0;
                any = true;
                consumed[i] = n_in;
                let carry = self.pool.checkout(*session).expect("session admitted");
                carries.push(Some(carry));
                let _ = session;
            }
            if !any {
                break;
            }
            // pad remaining rows with zero carries
            while carries.len() < b {
                carries.push(None);
            }
            let single = self.stream_entry_single();
            for cslot in &carries {
                match cslot {
                    Some(cr) => {
                        l_all.extend_from_slice(&cr.l);
                        u_all.extend_from_slice(&cr.u);
                    }
                    None => {
                        let z = StreamCarry::zeros(&single);
                        l_all.extend_from_slice(&z.l);
                        u_all.extend_from_slice(&z.u);
                    }
                }
            }
            let e = &self.stream_entry;
            let out = self.rt.run_with_param_buffer(
                e,
                self.params.buffer(),
                &[
                    Tensor::f32(l_all, &e.inputs[1].shape.clone()),
                    Tensor::f32(u_all, &e.inputs[2].shape.clone()),
                    Tensor::i32(toks, &[b, c]),
                    Tensor::i32(tgts, &[b, c]),
                    Tensor::f32(mask, &[b, c]),
                    Tensor::f32(active, &[b]),
                ],
            );
            let out = match out {
                Ok(o) => o,
                Err(err) => {
                    // fail every in-flight request in this wave
                    let msg = format!("{err:#}");
                    for (session, _, _, resp, _, _, _, _, _) in sessions.drain(..) {
                        self.pool.release(session);
                        let _ = resp.send(Err(anyhow!("stream step failed: {msg}")));
                    }
                    return;
                }
            };
            let l_new = out[0].as_f32().unwrap();
            let u_new = out[1].as_f32().unwrap();
            let nll = out[2].as_f32().unwrap();
            let cnt = out[3].as_f32().unwrap();
            let l_stride = single.inputs[1].numel();
            let u_stride = single.inputs[2].numel();
            for (i, cslot) in carries.into_iter().enumerate() {
                if let Some(mut cr) = cslot {
                    cr.l.clear();
                    cr.l.extend_from_slice(&l_new[i * l_stride..(i + 1) * l_stride]);
                    cr.u.clear();
                    cr.u.extend_from_slice(&u_new[i * u_stride..(i + 1) * u_stride]);
                    let s = &mut sessions[i];
                    self.pool.checkin(s.0, cr, consumed[i] as u64);
                    s.6 += nll[i] as f64;
                    s.7 += cnt[i] as f64;
                    s.8 += consumed[i];
                    self.stats.tokens_streamed.fetch_add(consumed[i] as u64, Ordering::Relaxed);
                }
            }
            // drop fully-drained sessions out of the wave
            let mut still = Vec::new();
            for s in sessions.drain(..) {
                let done = s.1.len().saturating_sub(s.8) <= 1;
                if done {
                    self.stats.feeds.fetch_add(1, Ordering::Relaxed);
                    self.stats.feed_latency.lock().unwrap().record(s.4.elapsed().as_secs_f64());
                    let _ = s.3.send(Ok(FeedResult { nll_sum: s.6, count: s.7, evicted: s.5 }));
                } else {
                    still.push(s);
                }
            }
            sessions = still;
            if sessions.is_empty() {
                break;
            }
        }
        // sessions left with <=1 token remaining: respond
        for s in sessions {
            self.stats.feeds.fetch_add(1, Ordering::Relaxed);
            let _ = s.3.send(Ok(FeedResult { nll_sum: s.6, count: s.7, evicted: s.5 }));
        }
    }

    fn run_generate(
        &mut self,
        session: u64,
        seed_token: i32,
        max_tokens: usize,
        stop: Option<i32>,
        sampling: Sampling,
        rng_seed: u64,
    ) -> Result<GenResult> {
        let mut rng = crate::util::rng::Rng::new(rng_seed ^ session);
        self.admit_session(session);
        let mut carry = self
            .pool
            .checkout(session)
            .ok_or_else(|| anyhow!("session {session} not available"))?;
        let e = &self.decode_entry;
        let mut out_tokens = Vec::new();
        // feed() consumes tokens pairwise (input -> target) and leaves the
        // final prompt token unconsumed; the caller passes it here.
        let mut token = seed_token;
        let mut produced = 0usize;
        let result = loop {
            if produced >= max_tokens {
                break Ok(());
            }
            let run = self.rt.run_with_param_buffer(
                e,
                self.params.buffer(),
                &[
                    Tensor::f32(std::mem::take(&mut carry.l), &carry.l_shape.clone()),
                    Tensor::f32(std::mem::take(&mut carry.u), &carry.u_shape.clone()),
                    Tensor::i32(vec![token], &[1]),
                ],
            );
            match run {
                Ok(mut out) => {
                    let logits = out.pop().unwrap().into_f32().unwrap();
                    carry.u = out.pop().unwrap().into_f32().unwrap();
                    carry.l = out.pop().unwrap().into_f32().unwrap();
                    token = sampling.sample(&logits, &mut rng) as i32;
                    out_tokens.push(token);
                    produced += 1;
                    if Some(token) == stop {
                        break Ok(());
                    }
                }
                Err(err) => break Err(err),
            }
        };
        self.pool.checkin(session, carry, produced as u64);
        result?;
        Ok(GenResult { tokens: out_tokens })
    }
}
