//! Bounded MPMC queue with blocking push (backpressure) and close
//! semantics — the admission-control primitive of the streaming server.

use std::collections::VecDeque;
use std::time::Duration;

use crate::util::sync::{Condvar, Mutex};

pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

struct Inner<T> {
    q: VecDeque<T>,
    closed: bool,
}

#[derive(Debug, PartialEq, Eq)]
pub enum PushError {
    Closed,
    Timeout,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        BoundedQueue {
            inner: Mutex::new(Inner { q: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Blocking push; applies backpressure when full. Err on close/timeout.
    pub fn push(&self, item: T, timeout: Duration) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        let deadline = std::time::Instant::now() + timeout;
        while g.q.len() >= self.cap && !g.closed {
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(PushError::Timeout);
            }
            let (ng, res) = self.not_full.wait_timeout(g, deadline - now).unwrap();
            g = ng;
            if res.timed_out() && g.q.len() >= self.cap && !g.closed {
                return Err(PushError::Timeout);
            }
        }
        if g.closed {
            return Err(PushError::Closed);
        }
        g.q.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking push; Err(item) if full or closed (load shedding).
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.q.len() >= self.cap {
            return Err(item);
        }
        g.q.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; None when closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(x) = g.q.pop_front() {
                self.not_full.notify_one();
                return Some(x);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop with timeout; None on timeout or closed+empty.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if let Some(x) = g.q.pop_front() {
                self.not_full.notify_one();
                return Some(x);
            }
            if g.closed {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (ng, _) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = ng;
        }
    }

    /// Drain up to `max` items without blocking (after one blocking pop —
    /// see Batcher). Returns possibly-empty vec.
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let n = g.q.len().min(max);
        let out: Vec<T> = g.q.drain(..n).collect();
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// True once [`BoundedQueue::close`] has been called (items already
    /// queued may still be drained). The serving scheduler polls this
    /// to cancel in-flight generations promptly on shutdown.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::Arc;
    use std::thread;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10);
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn backpressure_try_push() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(3));
        q.pop();
        q.try_push(3).unwrap();
    }

    #[test]
    fn push_timeout_when_full() {
        let q = BoundedQueue::new(1);
        q.try_push(1).unwrap();
        let e = q.push(2, Duration::from_millis(20)).unwrap_err();
        assert_eq!(e, PushError::Timeout);
    }

    #[test]
    fn close_wakes_consumers() {
        let q = Arc::new(BoundedQueue::<u32>::new(4));
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || q2.pop());
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn close_drains_remaining() {
        let q = BoundedQueue::new(4);
        q.try_push(7).unwrap();
        q.close();
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
        assert_eq!(q.try_push(8), Err(8));
    }

    #[test]
    fn producer_consumer_threads() {
        let q = Arc::new(BoundedQueue::new(8));
        let qp = Arc::clone(&q);
        let producer = thread::spawn(move || {
            for i in 0..1000u32 {
                qp.push(i, Duration::from_secs(5)).unwrap();
            }
            qp.close();
        });
        let mut got = Vec::new();
        while let Some(x) = q.pop() {
            got.push(x);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn drain_up_to_bounds() {
        let q = BoundedQueue::new(10);
        for i in 0..6 {
            q.try_push(i).unwrap();
        }
        let d = q.drain_up_to(4);
        assert_eq!(d, vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 2);
    }
}
