//! Token sampling policies for generation: greedy, temperature,
//! top-k, nucleus (top-p) — the serving-side decode controls.

use crate::metrics::{argmax, log_softmax};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampling {
    Greedy,
    /// softmax temperature (1.0 = model distribution)
    Temperature(f32),
    /// keep only the k most likely tokens, renormalise
    TopK(usize, f32),
    /// nucleus sampling: smallest set with cumulative prob >= p
    TopP(f32, f32),
}

impl Sampling {
    /// Parse "greedy" | "temp:0.8" | "topk:40:0.8" | "topp:0.9:1.0".
    pub fn parse(s: &str) -> Result<Sampling, String> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts[0] {
            "greedy" => Ok(Sampling::Greedy),
            "temp" => Ok(Sampling::Temperature(
                parts.get(1).and_then(|v| v.parse().ok()).ok_or("temp:T")?,
            )),
            "topk" => {
                let k = parts.get(1).and_then(|v| v.parse().ok()).ok_or("topk:K:T")?;
                let t = parts.get(2).and_then(|v| v.parse().ok()).unwrap_or(1.0);
                Ok(Sampling::TopK(k, t))
            }
            "topp" => {
                let p = parts.get(1).and_then(|v| v.parse().ok()).ok_or("topp:P:T")?;
                let t = parts.get(2).and_then(|v| v.parse().ok()).unwrap_or(1.0);
                Ok(Sampling::TopP(p, t))
            }
            other => Err(format!("unknown sampling '{other}'")),
        }
    }

    /// Draw the next token id from `logits`.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> usize {
        match *self {
            Sampling::Greedy => argmax(logits),
            Sampling::Temperature(t) => Self::draw(logits, t, None, None, rng),
            Sampling::TopK(k, t) => Self::draw(logits, t, Some(k), None, rng),
            Sampling::TopP(p, t) => Self::draw(logits, t, None, Some(p), rng),
        }
    }

    fn draw(
        logits: &[f32],
        temp: f32,
        top_k: Option<usize>,
        top_p: Option<f32>,
        rng: &mut Rng,
    ) -> usize {
        let temp = temp.max(1e-4);
        let scaled: Vec<f32> = logits.iter().map(|x| x / temp).collect();
        let logp = log_softmax(&scaled);
        // candidate set sorted by probability desc
        let mut order: Vec<usize> = (0..logp.len()).collect();
        // PANIC-OK: `order` is a permutation of 0..logp.len(), so every
        // index drawn from it is in bounds; log_softmax never yields
        // NaN (inputs are finite after the temp clamp), so the
        // comparator's unwrap cannot fire
        order.sort_by(|&a, &b| logp[b].partial_cmp(&logp[a]).unwrap());
        let mut keep = order.len();
        if let Some(k) = top_k {
            keep = keep.min(k.max(1));
        }
        if let Some(p) = top_p {
            let mut acc = 0.0f32;
            let mut np = 0usize;
            for &i in order.iter().take(keep) {
                // PANIC-OK: i comes from the 0..len permutation
                acc += logp[i].exp();
                np += 1;
                if acc >= p {
                    break;
                }
            }
            keep = np.max(1);
        }
        // PANIC-OK: keep <= order.len() by construction (min with len,
        // then only ever reduced), and i is drawn from the permutation
        let probs: Vec<f64> = order[..keep].iter().map(|&i| logp[i].exp() as f64).collect();
        // PANIC-OK: categorical returns an index < probs.len() = keep
        order[rng.categorical(&probs)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits() -> Vec<f32> {
        vec![0.0, 3.0, 1.0, -2.0, 2.0]
    }

    #[test]
    fn parse_forms() {
        assert_eq!(Sampling::parse("greedy").unwrap(), Sampling::Greedy);
        assert_eq!(Sampling::parse("temp:0.5").unwrap(), Sampling::Temperature(0.5));
        assert_eq!(Sampling::parse("topk:40:0.8").unwrap(), Sampling::TopK(40, 0.8));
        assert_eq!(Sampling::parse("topp:0.9").unwrap(), Sampling::TopP(0.9, 1.0));
        assert!(Sampling::parse("nope").is_err());
    }

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(1);
        assert_eq!(Sampling::Greedy.sample(&logits(), &mut rng), 1);
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            assert_eq!(Sampling::Temperature(0.01).sample(&logits(), &mut rng), 1);
        }
    }

    #[test]
    fn topk1_is_greedy() {
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            assert_eq!(Sampling::TopK(1, 1.0).sample(&logits(), &mut rng), 1);
        }
    }

    #[test]
    fn topk_excludes_tail() {
        let mut rng = Rng::new(4);
        for _ in 0..200 {
            let t = Sampling::TopK(2, 1.0).sample(&logits(), &mut rng);
            assert!(t == 1 || t == 4, "token {t} outside top-2");
        }
    }

    #[test]
    fn topp_small_keeps_head() {
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            // head prob of token 1 is ~0.59; p=0.5 keeps only it
            assert_eq!(Sampling::TopP(0.5, 1.0).sample(&logits(), &mut rng), 1);
        }
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let mut rng = Rng::new(6);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..500 {
            seen.insert(Sampling::Temperature(2.0).sample(&logits(), &mut rng));
        }
        assert!(seen.len() >= 4, "high temperature should explore: {seen:?}");
    }
}
