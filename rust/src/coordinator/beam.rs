//! Beam-search decoding over a step scorer — used by exp_mt for the
//! Table-2 BLEU (the paper's transformer baselines are conventionally
//! decoded with a small beam). The scorer abstraction keeps this
//! testable without PJRT: production passes the `s2s_decode` artifact.

/// Scores the next-token distribution given the current prefix.
pub trait StepScorer {
    /// log-probabilities [vocab] for position `prefix.len()` (the
    /// prefix always starts with BOS).
    fn logprobs(&mut self, prefix: &[i32]) -> Vec<f32>;
}

#[derive(Clone, Debug)]
struct Hyp {
    tokens: Vec<i32>,
    score: f32,
    done: bool,
}

/// Standard length-normalised beam search.
pub fn beam_search<S: StepScorer>(
    scorer: &mut S,
    bos: i32,
    eos: i32,
    beam: usize,
    max_len: usize,
    length_penalty: f32,
) -> Vec<i32> {
    let beam = beam.max(1);
    let mut hyps = vec![Hyp { tokens: vec![bos], score: 0.0, done: false }];
    for _ in 0..max_len {
        if hyps.iter().all(|h| h.done) {
            break;
        }
        let mut cands: Vec<Hyp> = Vec::new();
        for h in &hyps {
            if h.done {
                cands.push(h.clone());
                continue;
            }
            let logp = scorer.logprobs(&h.tokens);
            // expand the top `beam` continuations of this hypothesis
            let mut order: Vec<usize> = (0..logp.len()).collect();
            order.sort_by(|&a, &b| logp[b].partial_cmp(&logp[a]).unwrap());
            for &t in order.iter().take(beam) {
                let mut tokens = h.tokens.clone();
                tokens.push(t as i32);
                cands.push(Hyp {
                    score: h.score + logp[t],
                    done: t as i32 == eos,
                    tokens,
                });
            }
        }
        // keep the best `beam` by length-normalised score
        cands.sort_by(|a, b| {
            let na = norm(a, length_penalty);
            let nb = norm(b, length_penalty);
            nb.partial_cmp(&na).unwrap()
        });
        cands.truncate(beam);
        hyps = cands;
    }
    let best = hyps
        .into_iter()
        .max_by(|a, b| norm(a, length_penalty).partial_cmp(&norm(b, length_penalty)).unwrap())
        .unwrap();
    // strip BOS and EOS
    best.tokens[1..]
        .iter()
        .cloned()
        .take_while(|&t| t != eos)
        .collect()
}

fn norm(h: &Hyp, alpha: f32) -> f32 {
    let len = (h.tokens.len() as f32 - 1.0).max(1.0);
    h.score / len.powf(alpha)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic toy language: prefers the sequence [5, 6, 7, EOS],
    /// but a greedy trap at the first step prefers 9 (which leads to a
    /// dead end) — beam > 1 must recover the globally better path.
    struct Trap;

    const EOS: i32 = 2;

    impl StepScorer for Trap {
        fn logprobs(&mut self, prefix: &[i32]) -> Vec<f32> {
            let mut lp = vec![-10.0f32; 16];
            match prefix {
                [1] => {
                    lp[9] = -0.1; // greedy trap
                    lp[5] = -0.2;
                }
                [1, 9] => {
                    lp[EOS as usize] = -8.0; // dead end: forced bad EOS
                }
                [1, 5] => lp[6] = -0.1,
                [1, 5, 6] => lp[7] = -0.1,
                [1, 5, 6, 7] => lp[EOS as usize] = -0.1,
                _ => lp[EOS as usize] = -0.5,
            }
            lp
        }
    }

    #[test]
    fn greedy_falls_into_trap() {
        let out = beam_search(&mut Trap, 1, EOS, 1, 8, 0.0);
        assert_eq!(out[0], 9, "beam=1 should act greedily");
    }

    #[test]
    fn beam_escapes_trap() {
        let out = beam_search(&mut Trap, 1, EOS, 3, 8, 0.0);
        assert_eq!(out, vec![5, 6, 7], "beam=3 should find the better path");
    }

    #[test]
    fn max_len_respected() {
        struct Never;
        impl StepScorer for Never {
            fn logprobs(&mut self, _p: &[i32]) -> Vec<f32> {
                let mut lp = vec![-1.0f32; 8];
                lp[2] = -50.0; // EOS very unlikely
                lp[3] = -0.1;
                lp
            }
        }
        let out = beam_search(&mut Never, 1, 2, 2, 5, 0.0);
        assert!(out.len() <= 5);
    }

    #[test]
    fn length_penalty_prefers_longer() {
        // two paths: short [4, EOS] with higher per-token score, long
        // [5,5,5,EOS]; with alpha=1 normalisation the long one can win
        struct Two;
        impl StepScorer for Two {
            fn logprobs(&mut self, prefix: &[i32]) -> Vec<f32> {
                let mut lp = vec![-20.0f32; 8];
                match prefix.len() {
                    1 => {
                        lp[4] = -0.5;
                        lp[5] = -0.6;
                    }
                    2 if prefix[1] == 4 => lp[2] = -0.5,
                    _ => {
                        lp[5] = -0.6;
                        if prefix.len() >= 4 {
                            lp[2] = -0.1;
                        }
                    }
                }
                lp
            }
        }
        let greedy_len = beam_search(&mut Two, 1, 2, 1, 8, 0.0).len();
        let norm_len = beam_search(&mut Two, 1, 2, 4, 8, 1.0).len();
        assert!(norm_len >= greedy_len);
    }
}
