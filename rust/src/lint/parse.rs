//! Dependency-free Rust item parser for the deep lint tier: extracts
//! `fn` items with their spans, enclosing module path and `impl`
//! context from a scrubbed source (no syn, no regex — a brace-depth
//! scanner over the same scrubbed text the shallow rules match on).
//!
//! The parser only needs to be right about the constructs this crate
//! uses: `mod` / `impl Type` / `impl Trait for Type` / `trait` scopes,
//! attributes (`#[cfg(test)]` / `#[test]` mark an item and everything
//! inside it as test code, excluded from analysis), and nested items.
//! Closures are deliberately *not* items: their bodies stay part of
//! the enclosing function, which is exactly what reachability wants
//! (a `scatter_rows` job body is analyzed as part of its caller).

use super::scrub;

/// One `fn` item: where it is, what it is called, and the impl/trait
/// context that method-receiver resolution needs.
#[derive(Debug, Clone)]
pub struct FnItem {
    /// Bare function name (`feed_wave`).
    pub name: String,
    /// Module-qualified path (`coordinator::server::ModelThread::feed_wave`).
    pub qual: String,
    /// `impl Foo { … }` / `impl Trait for Foo { … }` → `Foo`.
    pub self_ty: Option<String>,
    /// `impl Trait for Foo { … }` or a `trait Trait { … }` default
    /// method → `Trait`.
    pub trait_name: Option<String>,
    /// 0-indexed line where the item's header (attrs skipped,
    /// signature included) begins.
    pub start_line: usize,
    /// 0-indexed line of the body's closing `}` (inclusive).
    pub end_line: usize,
    /// Under `#[cfg(test)]` / `#[test]` (directly or via an enclosing
    /// scope): excluded from the call graph and every deep rule.
    pub is_test: bool,
}

/// One parsed source file: the scrubbed text (for sink scans) plus the
/// extracted items.
#[derive(Debug)]
pub struct ParsedFile {
    /// Path as reported in findings (forward slashes).
    pub rel: String,
    /// Crate-relative module path (`net::worker`; empty for lib/main).
    pub module: String,
    /// Raw text — comments included, for `LINT-EDGE` / `PANIC-OK` /
    /// `F64-REDUCE` / `LINT-LOCK` marker scans.
    pub raw: String,
    pub scrubbed: String,
    pub fns: Vec<FnItem>,
}

/// Derive the crate-relative module path from a file path:
/// `…/src/net/worker.rs` → `net::worker`, `…/src/lint/mod.rs` →
/// `lint`, `…/src/lib.rs` → `` (crate root).
pub fn module_path(rel: &str) -> String {
    let rel = rel.replace('\\', "/");
    let after = match rel.rfind("src/") {
        Some(p) => &rel[p + 4..],
        None => rel.as_str(),
    };
    let after = after.strip_suffix(".rs").unwrap_or(after);
    let after = after.strip_suffix("/mod").unwrap_or(after);
    if after == "lib" || after == "main" {
        return String::new();
    }
    after.replace('/', "::")
}

/// Parse one file. `rel` is the reported path (also the module-path
/// source); `src` is the raw text (scrubbed here, once).
pub fn parse_file(rel: &str, src: &str) -> ParsedFile {
    let scrubbed = scrub(src);
    let module = module_path(rel);
    let fns = parse_items(&scrubbed, &module);
    ParsedFile { rel: rel.to_string(), module, raw: src.to_string(), scrubbed, fns }
}

enum Scope {
    Mod { name: String, test: bool },
    Impl { self_ty: Option<String>, trait_name: Option<String> },
    Fn { idx: usize },
    Other,
}

fn parse_items(scrubbed: &str, module: &str) -> Vec<FnItem> {
    let mut fns: Vec<FnItem> = Vec::new();
    let mut stack: Vec<Scope> = Vec::new();
    // Everything since the last `;` / `{` / `}`, newlines flattened to
    // spaces: when a `{` arrives, this is the item header (attributes
    // included — which is how `#[cfg(test)]` is seen) that tells us
    // what kind of scope just opened.
    let mut header = String::new();
    let mut header_line = 0usize;
    let mut line = 0usize;
    for c in scrubbed.chars() {
        match c {
            '\n' => {
                line += 1;
                header.push(' ');
            }
            ';' => header.clear(),
            '}' => {
                header.clear();
                if let Some(Scope::Fn { idx }) = stack.pop() {
                    fns[idx].end_line = line;
                }
            }
            '{' => {
                let scope =
                    classify_header(header.trim(), header_line, &stack, module, &mut fns);
                stack.push(scope);
                header.clear();
            }
            _ => {
                if header.trim().is_empty() && !c.is_whitespace() {
                    header_line = line;
                }
                header.push(c);
            }
        }
    }
    fns
}

fn classify_header(
    raw_header: &str,
    header_line: usize,
    stack: &[Scope],
    module: &str,
    fns: &mut Vec<FnItem>,
) -> Scope {
    let in_test = stack.iter().any(|s| match s {
        Scope::Mod { test, .. } => *test,
        Scope::Fn { idx } => fns[*idx].is_test,
        _ => false,
    });
    let own_test = raw_header.contains("#[cfg(test)]")
        || raw_header.contains("#[cfg(all(test")
        || raw_header.contains("#[test]");
    let h = strip_modifiers(strip_attrs(raw_header));
    if let Some(name) = fn_name(h) {
        let (self_ty, trait_name) = enclosing_impl(stack);
        let mut qual = String::new();
        if !module.is_empty() {
            qual.push_str(module);
            qual.push_str("::");
        }
        for s in stack {
            if let Scope::Mod { name, .. } = s {
                qual.push_str(name);
                qual.push_str("::");
            }
        }
        if let Some(t) = &self_ty {
            qual.push_str(t);
            qual.push_str("::");
        } else if let Some(t) = &trait_name {
            qual.push_str(t);
            qual.push_str("::");
        }
        qual.push_str(&name);
        fns.push(FnItem {
            name,
            qual,
            self_ty,
            trait_name,
            start_line: header_line,
            end_line: header_line,
            is_test: in_test || own_test,
        });
        return Scope::Fn { idx: fns.len() - 1 };
    }
    if let Some(rest) = keyword_rest(h, "mod") {
        return Scope::Mod { name: ident_prefix(rest), test: in_test || own_test };
    }
    if let Some(rest) = keyword_rest(h, "impl") {
        let (self_ty, trait_name) = parse_impl_header(rest);
        return Scope::Impl { self_ty, trait_name };
    }
    if let Some(rest) = keyword_rest(h, "trait") {
        return Scope::Impl { self_ty: None, trait_name: Some(ident_prefix(rest)) };
    }
    Scope::Other
}

/// The innermost `impl` / `trait` scope, if any.
fn enclosing_impl(stack: &[Scope]) -> (Option<String>, Option<String>) {
    for s in stack.iter().rev() {
        if let Scope::Impl { self_ty, trait_name } = s {
            return (self_ty.clone(), trait_name.clone());
        }
    }
    (None, None)
}

/// Skip leading attributes (`#[…]`, `#![…]`, possibly nested brackets).
fn strip_attrs(mut s: &str) -> &str {
    loop {
        s = s.trim_start();
        if !(s.starts_with("#[") || s.starts_with("#![")) {
            return s;
        }
        let open = match s.find('[') {
            Some(p) => p,
            None => return s,
        };
        let mut depth = 0usize;
        let mut end = None;
        for (i, c) in s[open..].char_indices() {
            match c {
                '[' => depth += 1,
                ']' => {
                    depth -= 1;
                    if depth == 0 {
                        end = Some(open + i + 1);
                        break;
                    }
                }
                _ => {}
            }
        }
        match end {
            Some(e) => s = &s[e..],
            None => return s,
        }
    }
}

/// Skip visibility/qualifier words (`pub`, `pub(crate)`, `unsafe`,
/// `const`, `async`, `extern`, `default`) before the item keyword.
fn strip_modifiers(mut s: &str) -> &str {
    loop {
        s = s.trim_start();
        let w = s.split_whitespace().next().unwrap_or("");
        let base = w.split('(').next().unwrap_or("");
        match base {
            "pub" | "unsafe" | "const" | "async" | "extern" | "default" if !w.is_empty() => {
                s = &s[w.len()..];
            }
            _ => return s,
        }
    }
}

/// `kw` must open the header (after attrs/modifiers) as a whole word.
fn keyword_rest<'a>(h: &'a str, kw: &str) -> Option<&'a str> {
    let rest = h.strip_prefix(kw)?;
    match rest.chars().next() {
        None => Some(rest),
        Some(c) if c.is_alphanumeric() || c == '_' => None,
        Some(_) => Some(rest),
    }
}

/// `fn name…` → `name`.
fn fn_name(h: &str) -> Option<String> {
    let rest = keyword_rest(h, "fn")?;
    let name = ident_prefix(rest);
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// Leading identifier of `s` (whitespace skipped).
fn ident_prefix(s: &str) -> String {
    s.trim_start()
        .chars()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect()
}

/// `…` after `impl`: `<'a> Cursor<'a>` → (Some("Cursor"), None);
/// `Mixer for Recurrence` → (Some("Recurrence"), Some("Mixer")).
fn parse_impl_header(rest: &str) -> (Option<String>, Option<String>) {
    let rest = skip_generics(rest.trim_start());
    // a ` where` clause never precedes the body-opening `{` we were
    // called for, but cut defensively
    let rest = match find_word(rest, "where") {
        Some(p) => &rest[..p],
        None => rest,
    };
    match find_word(rest, "for") {
        Some(p) => {
            let tr = last_type_segment(&rest[..p]);
            let ty = last_type_segment(&rest[p + 3..]);
            (ty, tr)
        }
        None => (last_type_segment(rest), None),
    }
}

/// Skip a leading `<…>` generic-parameter list (angle depth counted).
fn skip_generics(s: &str) -> &str {
    if !s.starts_with('<') {
        return s;
    }
    let mut depth = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '<' => depth += 1,
            '>' => {
                depth -= 1;
                if depth == 0 {
                    return &s[i + 1..];
                }
            }
            _ => {}
        }
    }
    s
}

/// Byte offset of `w` in `s` as a whole word, if present.
fn find_word(s: &str, w: &str) -> Option<usize> {
    let mut from = 0usize;
    while let Some(p) = s[from..].find(w) {
        let p = from + p;
        let before_ok =
            s[..p].chars().next_back().is_none_or(|c| !(c.is_alphanumeric() || c == '_'));
        let after_ok = s[p + w.len()..]
            .chars()
            .next()
            .is_none_or(|c| !(c.is_alphanumeric() || c == '_'));
        if before_ok && after_ok {
            return Some(p);
        }
        from = p + w.len();
    }
    None
}

/// The identifying segment of a type expression: strip `&`/`dyn`/`mut`
/// and generics, take the last `::` path segment.
/// `crate::wire::Frame<'a>` → `Frame`.
pub fn last_type_segment(s: &str) -> Option<String> {
    let mut s = s.trim();
    loop {
        let t = s.trim_start_matches(['&', ' ']);
        let t = t.strip_prefix("mut ").unwrap_or(t);
        let t = t.strip_prefix("dyn ").unwrap_or(t);
        if t == s {
            break;
        }
        s = t;
    }
    let head = match s.find('<') {
        Some(p) => &s[..p],
        None => s,
    };
    let seg = head.rsplit("::").next().unwrap_or("").trim();
    let seg: String = seg.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
    if seg.is_empty() {
        None
    } else {
        Some(seg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOLDEN: &str = r#"
pub struct Widget { n: usize }

impl Widget {
    pub fn poke(&self) -> usize { self.n }
}

impl std::fmt::Display for Widget {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.n)
    }
}

mod inner {
    pub fn helper() {}
    mod deeper {
        pub fn helper() {} // shadowed name, distinct qual
    }
}

trait Gadget {
    fn default_method(&self) -> usize {
        1
    }
}

fn free_standing(x: fn(usize) -> usize) -> usize {
    let closure = |v: usize| { v + 1 };
    x(closure(1))
}

#[cfg(test)]
mod tests {
    #[test]
    fn a_test() {
        fn nested_in_test() {}
        nested_in_test();
    }
}
"#;

    fn names(p: &ParsedFile) -> Vec<(String, bool)> {
        p.fns.iter().map(|f| (f.qual.clone(), f.is_test)).collect()
    }

    #[test]
    fn golden_item_extraction() {
        let p = parse_file("rust/src/gizmo/widget.rs", GOLDEN);
        assert_eq!(p.module, "gizmo::widget");
        let got = names(&p);
        let want: Vec<(String, bool)> = [
            ("gizmo::widget::Widget::poke", false),
            ("gizmo::widget::Widget::fmt", false),
            ("gizmo::widget::inner::helper", false),
            ("gizmo::widget::inner::deeper::helper", false),
            ("gizmo::widget::Gadget::default_method", false),
            ("gizmo::widget::free_standing", false),
            ("gizmo::widget::tests::a_test", true),
            ("gizmo::widget::tests::nested_in_test", true),
        ]
        .iter()
        .map(|(q, t)| (q.to_string(), *t))
        .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn impl_trait_for_records_both_sides() {
        let p = parse_file("src/x.rs", GOLDEN);
        let fmt = p.fns.iter().find(|f| f.name == "fmt").unwrap();
        assert_eq!(fmt.self_ty.as_deref(), Some("Widget"));
        assert_eq!(fmt.trait_name.as_deref(), Some("Display"));
        let poke = p.fns.iter().find(|f| f.name == "poke").unwrap();
        assert_eq!(poke.self_ty.as_deref(), Some("Widget"));
        assert_eq!(poke.trait_name, None);
        let dm = p.fns.iter().find(|f| f.name == "default_method").unwrap();
        assert_eq!(dm.self_ty, None);
        assert_eq!(dm.trait_name.as_deref(), Some("Gadget"));
    }

    #[test]
    fn cfg_test_scopes_and_attrs_mark_tests() {
        // a mid-file model_check module must not poison items after it
        let src = "fn early() {}\n#[cfg(all(test, model_check))]\nmod model_check {\n    fn inside() {}\n}\nfn late() {}\n";
        let p = parse_file("src/lib.rs", src);
        let got = names(&p);
        assert_eq!(
            got,
            vec![
                ("early".to_string(), false),
                ("model_check::inside".to_string(), true),
                ("late".to_string(), false),
            ]
        );
    }

    #[test]
    fn fn_spans_cover_bodies() {
        let p = parse_file("src/x.rs", GOLDEN);
        let free = p.fns.iter().find(|f| f.name == "free_standing").unwrap();
        let lines: Vec<&str> = p.scrubbed.lines().collect();
        let body = lines[free.start_line..=free.end_line].join("\n");
        assert!(body.contains("closure(1)"), "{body}");
    }

    #[test]
    fn module_paths() {
        assert_eq!(module_path("rust/src/net/worker.rs"), "net::worker");
        assert_eq!(module_path("src/lint/mod.rs"), "lint");
        assert_eq!(module_path("src/lib.rs"), "");
        assert_eq!(module_path("src/main.rs"), "");
    }

    #[test]
    fn multiline_signatures_and_generics() {
        let src = "impl<'a, T: Clone> Holder<'a, T> {\n    pub(crate) fn get(\n        &self,\n        k: usize,\n    ) -> &T {\n        &self.items[k]\n    }\n}\n";
        let p = parse_file("src/x.rs", src);
        assert_eq!(p.fns.len(), 1);
        let f = &p.fns[0];
        assert_eq!(f.name, "get");
        assert_eq!(f.self_ty.as_deref(), Some("Holder"));
        assert_eq!(f.start_line, 1, "span starts at the signature");
        assert_eq!(f.end_line, 6);
    }
}
