//! Crate-wide function-level call graph for the deep lint tier.
//!
//! Name resolution is heuristic but deliberately *over-approximate*:
//! a call site may resolve to several candidate callees, and
//! reachability unions them all — a false edge costs an allowlist
//! entry with a stated reason, a missing edge costs an invariant. The
//! rules, in resolution order:
//!
//! * `self.m(…)` — methods of the enclosing `impl` type (both its
//!   inherent and trait impl blocks), then of the enclosing trait.
//! * `recv.m(…)` — the receiver ident's declared types (a crate-wide
//!   `ident: Type` scan, smart-pointer/cell wrappers unwrapped), then
//!   every crate method named `m` unless `m` is on the deny list of
//!   ubiquitous std names (`push`, `iter`, `get`, …) — those resolve
//!   only through a typed receiver.
//! * `Type::m(…)` / `Self::m(…)` — the impl-method index.
//! * `path::f(…)` — free functions named `f`, filtered by module-path
//!   suffix; bare `f(…)` prefers same-file, then same-module, then
//!   every candidate.
//! * `// LINT-EDGE: path::to::fn` — the escape hatch for calls the
//!   scanner cannot see (dyn dispatch through erased closures, fn
//!   pointers): adds an edge from the enclosing function to every fn
//!   whose qualified path ends with the given suffix.
//!
//! Closures are part of their enclosing function (see [`super::parse`]),
//! so a job body enqueued from `scatter_rows`'s *call site* is analyzed
//! as part of that caller.

use std::collections::{BTreeMap, BTreeSet};

use super::parse::{FnItem, ParsedFile};

/// Wrapper types unwrapped when recording an ident's declared type:
/// `cache: Mutex<Option<Panels>>` declares `cache` as a `Panels`
/// receiver for method resolution (and a `Mutex<HashMap<…>>` field
/// still counts as a `HashMap` ident for the determinism pass).
const WRAPPERS: [&str; 9] =
    ["Arc", "Box", "Rc", "Mutex", "RwLock", "RefCell", "Cell", "Option", "MutexGuard"];

/// Method names that are overwhelmingly std's when the receiver type
/// is unknown. An untyped `x.push(…)` must not resolve to every crate
/// method named `push`; typed receivers still resolve normally.
const DENY_UNTYPED_METHODS: [&str; 77] = [
    "recv", "recv_timeout", "try_recv",
    "push", "pop", "len", "is_empty", "iter", "iter_mut", "into_iter", "get", "get_mut",
    "insert", "remove", "contains", "contains_key", "clone", "next", "extend", "drain",
    "clear", "take", "map", "and_then", "or_else", "unwrap", "expect", "unwrap_or",
    "unwrap_or_else", "unwrap_or_default", "ok_or", "ok_or_else", "as_ref", "as_mut",
    "as_str", "as_slice", "as_bytes", "to_string", "to_owned", "entry", "or_insert",
    "or_insert_with", "keys", "values", "split", "trim", "parse", "join", "send", "min",
    "max", "abs", "sqrt", "exp", "ln", "powi", "powf", "to_vec", "collect", "sum", "fold",
    "rev", "enumerate", "zip", "chain", "filter", "any", "all", "find", "position",
    "count", "last", "first", "copied", "cloned", "flatten", "into_inner", "front",
];

/// The crate call graph: flattened non-test fns plus per-call-site
/// edges (`edges[n]` = `(callee node, 0-indexed call-site line)`).
pub struct CallGraph {
    pub files: Vec<ParsedFile>,
    /// node → (file index, fn index within that file)
    pub nodes: Vec<(usize, usize)>,
    pub edges: Vec<Vec<(usize, usize)>>,
    /// Per-file idents declared with a `HashMap`/`HashSet` type
    /// (wrappers unwrapped) — the determinism pass's iteration targets.
    pub hash_idents: Vec<BTreeSet<String>>,
    /// Per-file idents declared `f32` — the `F64-REDUCE` pass's
    /// accumulator candidates.
    pub f32_idents: Vec<BTreeSet<String>>,
}

impl CallGraph {
    pub fn item(&self, n: usize) -> &FnItem {
        let (fi, ii) = self.nodes[n];
        &self.files[fi].fns[ii]
    }

    pub fn file_of(&self, n: usize) -> &ParsedFile {
        &self.files[self.nodes[n].0]
    }

    /// Nodes whose qualified path ends with `suffix` (`::`-aligned).
    pub fn find_by_suffix(&self, suffix: &str) -> Vec<usize> {
        let tail = format!("::{suffix}");
        (0..self.nodes.len())
            .filter(|&n| {
                let q = &self.item(n).qual;
                q == suffix || q.ends_with(&tail)
            })
            .collect()
    }
}

/// Build the graph over already-parsed files.
pub fn build(files: Vec<ParsedFile>) -> CallGraph {
    let mut nodes: Vec<(usize, usize)> = Vec::new();
    for (fi, f) in files.iter().enumerate() {
        for (ii, it) in f.fns.iter().enumerate() {
            if !it.is_test {
                nodes.push((fi, ii));
            }
        }
    }
    let mut r = Resolver {
        files: &files,
        nodes: &nodes,
        by_name: BTreeMap::new(),
        by_ty_method: BTreeMap::new(),
        by_method: BTreeMap::new(),
        ty_of: BTreeMap::new(),
    };
    for (n, &(fi, ii)) in nodes.iter().enumerate() {
        let it = &files[fi].fns[ii];
        r.by_name.entry(it.name.clone()).or_default().push(n);
        if it.self_ty.is_some() || it.trait_name.is_some() {
            r.by_method.entry(it.name.clone()).or_default().push(n);
        }
        if let Some(t) = &it.self_ty {
            r.by_ty_method.entry((t.clone(), it.name.clone())).or_default().push(n);
        }
        if let Some(t) = &it.trait_name {
            r.by_ty_method.entry((t.clone(), it.name.clone())).or_default().push(n);
        }
    }
    // -- declared-type scan ------------------------------------------
    let mut hash_idents: Vec<BTreeSet<String>> = Vec::new();
    let mut f32_idents: Vec<BTreeSet<String>> = Vec::new();
    for f in &files {
        let mut hashes = BTreeSet::new();
        let mut floats = BTreeSet::new();
        for line in f.scrubbed.lines() {
            scan_decls(line, |ident, ty| {
                if ty == "HashMap" || ty == "HashSet" {
                    hashes.insert(ident.to_string());
                }
                if ty == "f32" {
                    floats.insert(ident.to_string());
                }
                if ty.starts_with(|c: char| c.is_ascii_uppercase()) {
                    r.ty_of.entry(ident.to_string()).or_default().insert(ty.to_string());
                }
            });
        }
        hash_idents.push(hashes);
        f32_idents.push(floats);
    }
    // -- edges -------------------------------------------------------
    let mut edges: Vec<Vec<(usize, usize)>> = Vec::with_capacity(nodes.len());
    for (n, &(fi, ii)) in nodes.iter().enumerate() {
        let f = &files[fi];
        let it = &f.fns[ii];
        let code: Vec<&str> = f.scrubbed.lines().collect();
        let raw: Vec<&str> = f.raw.lines().collect();
        let mut out: BTreeSet<(usize, usize)> = BTreeSet::new();
        let hi = it.end_line.min(code.len().saturating_sub(1));
        for line_no in it.start_line..=hi {
            for call in find_calls(code[line_no]) {
                // the fn's own signature (`fn name(…`) is not a call
                if line_no == it.start_line
                    && call.receiver.is_none()
                    && call.segs.len() == 1
                    && call.segs[0] == it.name
                {
                    continue;
                }
                for t in r.resolve(&call, it, fi) {
                    if t != n {
                        out.insert((t, line_no));
                    }
                }
            }
            // escape hatch: dyn / fn-pointer dispatch declared by hand
            if let Some(p) = raw.get(line_no).and_then(|l| l.find("LINT-EDGE:")) {
                let spec = &raw[line_no][p + "LINT-EDGE:".len()..];
                for name in spec.split(',') {
                    let name = name.trim();
                    if name.is_empty() {
                        continue;
                    }
                    let tail = format!("::{name}");
                    for (t, &(tfi, tii)) in nodes.iter().enumerate() {
                        let q = &files[tfi].fns[tii].qual;
                        if (q == name || q.ends_with(&tail)) && t != n {
                            out.insert((t, line_no));
                        }
                    }
                }
            }
        }
        edges.push(out.into_iter().collect());
    }
    CallGraph { files, nodes, edges, hash_idents, f32_idents }
}

struct Resolver<'a> {
    files: &'a [ParsedFile],
    nodes: &'a [(usize, usize)],
    by_name: BTreeMap<String, Vec<usize>>,
    by_ty_method: BTreeMap<(String, String), Vec<usize>>,
    by_method: BTreeMap<String, Vec<usize>>,
    ty_of: BTreeMap<String, BTreeSet<String>>,
}

impl Resolver<'_> {
    fn module_of(&self, n: usize) -> &str {
        &self.files[self.nodes[n].0].module
    }

    fn item_of(&self, n: usize) -> &FnItem {
        let (fi, fj) = self.nodes[n];
        &self.files[fi].fns[fj]
    }

    fn resolve(&self, call: &Call, it: &FnItem, fi: usize) -> Vec<usize> {
        let last = match call.segs.last() {
            Some(s) => s.as_str(),
            None => return Vec::new(),
        };
        // -- method call ---------------------------------------------
        if let Some(recv) = &call.receiver {
            let mut tys: Vec<String> = Vec::new();
            match recv.as_deref() {
                Some("self") | Some("Self") => {
                    tys.extend(it.self_ty.clone());
                    tys.extend(it.trait_name.clone());
                }
                Some(ident) => {
                    if let Some(set) = self.ty_of.get(ident) {
                        tys.extend(set.iter().cloned());
                    }
                }
                None => {}
            }
            let mut hits: BTreeSet<usize> = BTreeSet::new();
            for t in &tys {
                if let Some(v) = self.by_ty_method.get(&(t.clone(), last.to_string())) {
                    hits.extend(v.iter().copied());
                }
            }
            if !hits.is_empty() {
                return hits.into_iter().collect();
            }
            if DENY_UNTYPED_METHODS.contains(&last) {
                return Vec::new();
            }
            // untyped fallback: like bare calls, prefer same-file
            // methods — `c.vec_i32()` inside `wire.rs` means the
            // `Cursor` helper next to it, not a same-named method in
            // another subsystem
            let cands = self.by_method.get(last).cloned().unwrap_or_default();
            let same_file: Vec<usize> =
                cands.iter().copied().filter(|&t| self.nodes[t].0 == fi).collect();
            if !same_file.is_empty() {
                return same_file;
            }
            return cands;
        }
        // -- Type::m / Self::m / path::f -----------------------------
        if call.segs.len() >= 2 {
            let prev = &call.segs[call.segs.len() - 2];
            let prev_ty: Option<String> = if prev == "Self" {
                it.self_ty.clone().or_else(|| it.trait_name.clone())
            } else if prev.starts_with(|c: char| c.is_ascii_uppercase()) {
                Some(prev.clone())
            } else {
                None
            };
            if let Some(t) = prev_ty {
                return self.by_ty_method.get(&(t, last.to_string())).cloned().unwrap_or_default();
            }
            let suffix: Vec<&str> = call.segs[..call.segs.len() - 1]
                .iter()
                .map(String::as_str)
                .filter(|s| !matches!(*s, "crate" | "super" | "self" | "std"))
                .collect();
            let cands = self.by_name.get(last).cloned().unwrap_or_default();
            if suffix.is_empty() {
                return cands;
            }
            let suffix = suffix.join("::");
            let tail = format!("::{suffix}");
            return cands
                .into_iter()
                .filter(|&t| {
                    let m = self.module_of(t);
                    m == suffix || m.ends_with(&tail)
                })
                .collect();
        }
        // -- bare name: same file, then same module, then all --------
        // Only free functions: a bare `next()` can never invoke a
        // method (methods need `self.` / `Type::`), so a local closure
        // shadowing a crate method name must not create an edge to it.
        let mut cands = self.by_name.get(last).cloned().unwrap_or_default();
        cands.retain(|&t| {
            let item = self.item_of(t);
            item.self_ty.is_none() && item.trait_name.is_none()
        });
        let same_file: Vec<usize> =
            cands.iter().copied().filter(|&t| self.nodes[t].0 == fi).collect();
        if !same_file.is_empty() {
            return same_file;
        }
        let module = &self.files[fi].module;
        let same_mod: Vec<usize> =
            cands.iter().copied().filter(|&t| self.module_of(t) == module).collect();
        if !same_mod.is_empty() {
            return same_mod;
        }
        cands
    }
}

/// One syntactic call site.
#[derive(Debug, PartialEq, Eq)]
pub struct Call {
    /// `a::b::f(` → `["a", "b", "f"]`; `x.m(` → `["m"]`.
    pub segs: Vec<String>,
    /// `Some(Some(ident))` for `ident.m(` (last receiver ident:
    /// `self.a.b.m(` → `b`), `Some(None)` for a temporary receiver
    /// (`….m(` after `)` / `]`), `None` for non-method calls.
    pub receiver: Option<Option<String>>,
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn read_ident_back(chars: &[char], end: usize) -> (usize, String) {
    let mut s = end;
    while s > 0 && is_ident_char(chars[s - 1]) {
        s -= 1;
    }
    (s, chars[s..end].iter().collect())
}

/// Extract the call sites on one scrubbed line.
pub fn find_calls(line: &str) -> Vec<Call> {
    let chars: Vec<char> = line.chars().collect();
    let mut out = Vec::new();
    for p in 0..chars.len() {
        if chars[p] != '(' || p == 0 {
            continue;
        }
        let mut e = p;
        if chars[e - 1] == '!' {
            continue; // macro invocation — handled as a textual sink
        }
        // turbofish: `f::<T>(` — skip the generic args back to `::`
        if chars[e - 1] == '>' {
            let mut depth = 0usize;
            let mut q = e;
            let mut open = None;
            while q > 0 {
                q -= 1;
                match chars[q] {
                    '>' => depth += 1,
                    '<' => {
                        depth -= 1;
                        if depth == 0 {
                            open = Some(q);
                            break;
                        }
                    }
                    _ => {}
                }
            }
            match open {
                Some(q) if q >= 2 && chars[q - 1] == ':' && chars[q - 2] == ':' => e = q - 2,
                _ => continue,
            }
        }
        let (s0, seg0) = read_ident_back(&chars, e);
        if seg0.is_empty() || seg0.chars().next().is_some_and(|c| c.is_ascii_digit()) {
            continue;
        }
        // `drop(x)` is always `std::mem::drop` — Rust forbids explicit
        // `Drop::drop` calls — so resolving it to the crate's `fn drop`
        // impls would wire every value-drop to every destructor. A
        // destructor edge that matters is declared with `LINT-EDGE:`.
        if matches!(
            seg0.as_str(),
            "if" | "while"
                | "match"
                | "for"
                | "in"
                | "return"
                | "loop"
                | "move"
                | "fn"
                | "as"
                | "drop"
        ) {
            continue;
        }
        let mut segs = vec![seg0];
        let mut q = s0;
        while q >= 2 && chars[q - 1] == ':' && chars[q - 2] == ':' {
            let (s, seg) = read_ident_back(&chars, q - 2);
            if seg.is_empty() {
                break;
            }
            segs.push(seg);
            q = s;
        }
        segs.reverse();
        let receiver = if q >= 1 && chars[q - 1] == '.' && segs.len() == 1 {
            let before = q - 1;
            if before > 0 && (chars[before - 1] == ')' || chars[before - 1] == ']') {
                Some(None) // chained off a temporary
            } else {
                let (_, r) = read_ident_back(&chars, before);
                if r.is_empty() {
                    Some(None)
                } else {
                    Some(Some(r))
                }
            }
        } else {
            None
        };
        out.push(Call { segs, receiver });
    }
    out
}

/// Scan one scrubbed line for `ident: Type` declarations (struct
/// fields, fn params, `let` annotations) and report
/// `(ident, outermost-non-wrapper type segment)` pairs.
fn scan_decls(line: &str, mut f: impl FnMut(&str, &str)) {
    let chars: Vec<char> = line.chars().collect();
    for p in 0..chars.len() {
        if chars[p] != ':' {
            continue;
        }
        // skip `::` paths
        if p + 1 < chars.len() && chars[p + 1] == ':' {
            continue;
        }
        if p > 0 && chars[p - 1] == ':' {
            continue;
        }
        let (s, ident) = read_ident_back(&chars, p);
        if ident.is_empty() || ident.chars().next().is_some_and(|c| c.is_ascii_digit()) {
            continue;
        }
        // the ident must start a token (not `foo.bar:` etc.)
        if s > 0 && (chars[s - 1] == '.' || chars[s - 1] == '\'') {
            continue;
        }
        let rest: String = chars[p + 1..].iter().collect();
        if let Some(ty) = declared_type(&rest) {
            f(&ident, &ty);
        }
    }
}

/// First type segment of a declaration tail, wrappers unwrapped:
/// ` Mutex<HashMap<u64, X>>,` → `HashMap`.
fn declared_type(s: &str) -> Option<String> {
    let mut s = s.trim_start();
    loop {
        let t = s.trim_start_matches(['&', ' ']);
        let t = t.strip_prefix("mut ").unwrap_or(t);
        let t = t.strip_prefix("dyn ").unwrap_or(t);
        let t = t.strip_prefix("'static ").unwrap_or(t);
        if t == s {
            break;
        }
        s = t;
    }
    // leading path: a::b::Seg — keep only the final segment
    let mut seg = String::new();
    let mut rest = s;
    loop {
        let this: String =
            rest.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
        if this.is_empty() {
            break;
        }
        let after = &rest[this.len()..];
        if let Some(stripped) = after.strip_prefix("::") {
            rest = stripped;
            continue;
        }
        seg = this;
        rest = after;
        break;
    }
    if seg.is_empty() || seg.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        return None;
    }
    if WRAPPERS.contains(&seg.as_str()) {
        if let Some(inner) = rest.strip_prefix('<') {
            return declared_type(inner);
        }
    }
    Some(seg)
}

#[cfg(test)]
mod tests {
    use super::super::parse::parse_file;
    use super::*;

    fn graph_of(sources: &[(&str, &str)]) -> CallGraph {
        build(sources.iter().map(|(rel, src)| parse_file(rel, src)).collect())
    }

    fn node_named(g: &CallGraph, qual_suffix: &str) -> usize {
        let v = g.find_by_suffix(qual_suffix);
        assert_eq!(v.len(), 1, "ambiguous or missing {qual_suffix}: {v:?}");
        v[0]
    }

    fn callees(g: &CallGraph, n: usize) -> Vec<String> {
        g.edges[n].iter().map(|&(t, _)| g.item(t).qual.clone()).collect()
    }

    #[test]
    fn free_and_module_path_calls_resolve() {
        let g = graph_of(&[
            (
                "src/alpha.rs",
                "pub fn entry() { helper(); crate::beta::helper(); }\nfn helper() {}\n",
            ),
            ("src/beta.rs", "pub fn helper() {}\n"),
        ]);
        let n = node_named(&g, "alpha::entry");
        let c = callees(&g, n);
        // bare `helper()` prefers the same file; the path call crosses
        assert_eq!(c, vec!["alpha::helper".to_string(), "beta::helper".to_string()]);
    }

    #[test]
    fn shadowed_names_prefer_locals_but_paths_disambiguate() {
        let g = graph_of(&[
            ("src/a.rs", "pub fn go() { work(); }\npub fn work() {}\n"),
            ("src/b.rs", "pub fn work() {}\npub fn go2() { work(); a::work(); }\n"),
        ]);
        let c = callees(&g, node_named(&g, "b::go2"));
        // edges sort by node index: a::work precedes b::work
        assert_eq!(c, vec!["a::work".to_string(), "b::work".to_string()]);
    }

    #[test]
    fn method_receiver_resolution_via_declared_types() {
        let src = "\
pub struct Engine { core: Core }
pub struct Core;
impl Core {
    pub fn step(&self) {}
}
impl Engine {
    pub fn tick(&self) {
        self.core.step();
        self.helper();
    }
    fn helper(&self) {}
}
";
        let g = graph_of(&[("src/m.rs", src)]);
        let c = callees(&g, node_named(&g, "Engine::tick"));
        assert_eq!(c, vec!["m::Core::step".to_string(), "m::Engine::helper".to_string()]);
    }

    #[test]
    fn trait_impls_index_under_both_names() {
        let src = "\
pub trait Mixer {
    fn token_step(&self);
}
pub struct Rec;
impl Mixer for Rec {
    fn token_step(&self) {}
}
pub struct Holder { mixer: Box<dyn Mixer> }
impl Holder {
    pub fn go(&self) {
        self.mixer.token_step();
    }
}
";
        let g = graph_of(&[("src/m.rs", src)]);
        let c = callees(&g, node_named(&g, "Holder::go"));
        assert_eq!(c, vec!["m::Rec::token_step".to_string()]);
    }

    #[test]
    fn deny_list_blocks_untyped_std_names() {
        let src = "\
pub struct Q;
impl Q {
    pub fn push(&self) {}
}
pub fn go(v: &mut Vec<i32>) {
    v.push(1);
}
";
        let g = graph_of(&[("src/m.rs", src)]);
        // `v` is declared Vec — no crate impl — and `push` is denied
        // for the untyped fallback: no edge to Q::push
        assert!(callees(&g, node_named(&g, "m::go")).is_empty());
    }

    #[test]
    fn lint_edge_marker_adds_edges() {
        let src = "\
pub fn job_body() {}
pub fn dispatch(f: fn()) {
    f(); // LINT-EDGE: job_body
}
";
        let g = graph_of(&[("src/m.rs", src)]);
        let c = callees(&g, node_named(&g, "m::dispatch"));
        assert_eq!(c, vec!["m::job_body".to_string()]);
    }

    #[test]
    fn bare_calls_never_resolve_to_methods() {
        let src = "\
pub struct T;
impl T {
    pub fn next(&self) {}
}
pub fn go() {
    let mut next = || 3;
    next();
}
";
        // a bare `next()` cannot invoke `T::next` (methods need a
        // receiver), so a local closure shadowing a method name must
        // not create an edge to it
        let g = graph_of(&[("src/m.rs", src)]);
        assert!(callees(&g, node_named(&g, "m::go")).is_empty());
    }

    #[test]
    fn drop_calls_are_not_edges() {
        let src = "\
pub struct G;
impl Drop for G {
    fn drop(&mut self) {}
}
pub fn go(g: G) {
    drop(g);
}
";
        let g = graph_of(&[("src/m.rs", src)]);
        assert!(callees(&g, node_named(&g, "m::go")).is_empty());
    }

    #[test]
    fn untyped_methods_prefer_same_file() {
        let wire = "\
pub struct Cursor;
impl Cursor {
    pub fn vec_i32(&mut self) {}
}
pub fn decode() {
    let mut cur = Cursor;
    cur.vec_i32();
}
";
        let prop = "\
pub struct Gen;
impl Gen {
    pub fn vec_i32(&mut self) {}
}
";
        // `cur` has no `ident: Type` declaration anywhere, so this is
        // the untyped fallback: same-file candidates win
        let g = graph_of(&[
            ("src/net/wire.rs", wire),
            ("src/util/prop.rs", prop),
            ("src/other.rs", "pub fn kick(x: &mut Unknown) { x.vec_i32(); }\n"),
        ]);
        let c = callees(&g, node_named(&g, "wire::decode"));
        assert_eq!(c, vec!["net::wire::Cursor::vec_i32".to_string()]);
        // an untyped receiver in a third file still fans out to all
        let c = callees(&g, node_named(&g, "other::kick"));
        assert_eq!(c.len(), 2, "{c:?}");
    }

    #[test]
    fn cfg_test_fns_are_excluded() {
        let src = "\
pub fn runtime() {}
#[cfg(test)]
mod tests {
    pub fn fixture() { super::runtime(); }
}
";
        let g = graph_of(&[("src/m.rs", src)]);
        assert!(g.find_by_suffix("fixture").is_empty());
    }

    #[test]
    fn hash_and_f32_idents_recorded() {
        let src = "\
use std::collections::HashMap;
pub struct S {
    sessions: crate::util::sync::Mutex<HashMap<u64, u32>>,
    total: f32,
}
";
        let g = graph_of(&[("src/m.rs", src)]);
        assert!(g.hash_idents[0].contains("sessions"));
        assert!(g.f32_idents[0].contains("total"));
    }

    #[test]
    fn call_site_extraction_forms() {
        let calls = find_calls("let x = a.b.m(1) + free(2) + path::f(3) + IT::new(4);");
        let forms: Vec<(Vec<&str>, Option<Option<&str>>)> = calls
            .iter()
            .map(|c| {
                (
                    c.segs.iter().map(String::as_str).collect(),
                    c.receiver.as_ref().map(|r| r.as_deref()),
                )
            })
            .collect();
        assert_eq!(
            forms,
            vec![
                (vec!["m"], Some(Some("b"))),
                (vec!["free"], None),
                (vec!["path", "f"], None),
                (vec!["IT", "new"], None),
            ]
        );
        // macros and turbofish
        assert!(find_calls("format!(\"x\")").is_empty());
        let tf = find_calls("v.collect::<Vec<_>>()");
        assert_eq!(tf.len(), 1);
        assert_eq!(tf[0].segs, vec!["collect".to_string()]);
    }
}
