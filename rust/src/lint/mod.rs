//! `stlt lint` — dependency-free concurrency-hygiene lint for the
//! crate's own sources (DESIGN.md-style substrate build: no syn, no
//! regex — a hand-rolled scrubber plus token-level line scans).
//!
//! The rules encode the invariants the model checker
//! ([`crate::util::chk`]) and the sanitizer CI wall rest on:
//!
//! * **unsafe-safety** — every `unsafe` keyword must sit under an
//!   adjacent `// SAFETY:` comment naming the invariant it relies on.
//! * **static-mut** — `static mut` is banned outright (the facade's
//!   atomics or `OnceLock` cover every legitimate use).
//! * **unwrap** — `.unwrap()` / `.expect(` are banned in non-test
//!   runtime code; servers must degrade, not abort. Exceptions live in
//!   the committed allowlist (`lint.allow`) — and `net/` must have
//!   none: a remote peer's bytes must never reach a panic.
//! * **ordering-comment** — every relaxed/acquire/release atomic
//!   ordering (`Ordering::Relaxed`, `::Acquire`, `::Release`,
//!   `::AcqRel`) needs an adjacent `// ORDERING:` comment arguing why
//!   that ordering suffices. `SeqCst` is exempt: it is the
//!   safe-by-default choice, so it needs no argument.
//! * **std-sync** — `std::sync` may only be named by the facade
//!   (`util/sync.rs`) and the checker it swaps in (`util/chk.rs`).
//!   Everything else must import through `crate::util::sync`, or the
//!   model-check build silently loses coverage of that site.
//!
//! Scanning is scrub-then-match: string literals, char literals and
//! comments are blanked (newlines preserved) before pattern checks, so
//! `"std::sync"` in a doc comment or test fixture never trips a rule.
//! Suppressions come from an allowlist of `rule path` lines; unused
//! entries are themselves errors (`stale-allow`), which keeps the debt
//! ledger honest as call sites are burned down.
//!
//! The token-level rules above are the shallow tier. `stlt lint --deep`
//! layers a call-graph-aware tier on top of the same scrubber:
//!
//! * [`parse`] — a dependency-free item parser (fn/impl/mod spans,
//!   `cfg(test)` awareness) over scrubbed sources.
//! * [`graph`] — a crate-wide function-level call graph (module-path +
//!   method-receiver name resolution, `// LINT-EDGE:` escape hatch for
//!   dyn/fn-pointer edges).
//! * [`deep`] — reachability rule passes from the declared hot-path
//!   roots: alloc-free / non-blocking / panic-free decode, and the
//!   bitwise-determinism rules (no hash-order iteration, no f32
//!   scalar reductions in `// F64-REDUCE` functions, no wall-clock
//!   reads feeding tensor math). Ledger: `lint_deep.allow`.
//! * [`locks`] — a static lock-order graph over the `util::sync`
//!   facade (which locks are held across calls that acquire others),
//!   emitted as JSON and failed on cycles — the static complement of
//!   the model checker in [`crate::util::chk`].

pub mod deep;
pub mod graph;
pub mod locks;
pub mod parse;

pub use deep::run_deep;

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// One lint finding, pointing at a 1-indexed source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

pub const RULE_UNSAFE: &str = "unsafe-safety";
pub const RULE_STATIC_MUT: &str = "static-mut";
pub const RULE_UNWRAP: &str = "unwrap";
pub const RULE_ORDERING: &str = "ordering-comment";
pub const RULE_STD_SYNC: &str = "std-sync";
pub const RULE_STALE_ALLOW: &str = "stale-allow";

/// Files allowed to name `std::sync` directly: the facade itself and
/// the model checker it routes to under `--cfg model_check`.
const STD_SYNC_EXEMPT: [&str; 2] = ["util/sync.rs", "util/chk.rs"];

/// Blank string/char literals and comments (to spaces, newlines kept)
/// so pattern checks only ever see code. Handles line comments, nested
/// block comments, escapes, raw strings (`r"…"`, `r#"…"#`, `br…`) and
/// the char-literal / lifetime ambiguity.
fn scrub(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let mut out = String::with_capacity(src.len());
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        // line comment
        if c == '/' && b.get(i + 1) == Some(&'/') {
            while i < b.len() && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // block comment (Rust block comments nest)
        if c == '/' && b.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            out.push_str("  ");
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                    depth += 1;
                    out.push_str("  ");
                    i += 2;
                } else if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        // raw string: r"…" | r#"…"# (optionally b-prefixed), only when
        // the r/b does not continue an identifier
        let prev_ident =
            out.as_bytes().last().is_some_and(|&p| p.is_ascii_alphanumeric() || p == b'_');
        if (c == 'r' || c == 'b') && !prev_ident {
            let mut j = i + 1;
            if c == 'b' && b.get(j) == Some(&'r') {
                j += 1;
            }
            let mut hashes = 0usize;
            while b.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            if b.get(j) == Some(&'"') {
                for k in i..=j {
                    out.push(blank(b[k]));
                }
                i = j + 1;
                // scan to `"` followed by `hashes` `#`s
                'raw: while i < b.len() {
                    if b[i] == '"' && b[i + 1..].iter().take(hashes).filter(|&&h| h == '#').count() == hashes {
                        for k in i..(i + 1 + hashes).min(b.len()) {
                            out.push(blank(b[k]));
                        }
                        i += 1 + hashes;
                        break 'raw;
                    }
                    out.push(blank(b[i]));
                    i += 1;
                }
                continue;
            }
        }
        // plain (or byte) string literal
        if c == '"' {
            out.push(' ');
            i += 1;
            while i < b.len() && b[i] != '"' {
                if b[i] == '\\' && i + 1 < b.len() {
                    out.push(blank(b[i]));
                    out.push(blank(b[i + 1]));
                    i += 2;
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
            if i < b.len() {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if b.get(i + 1) == Some(&'\\') {
                // escaped char literal ('\n', '\'', '\x7f'): blank the
                // quote, the backslash and the escaped char, then
                // everything up to the closing quote
                out.push_str("   ");
                i += 3;
                while i < b.len() && b[i] != '\'' {
                    out.push(blank(b[i]));
                    i += 1;
                }
                if i < b.len() {
                    out.push(' ');
                    i += 1;
                }
                continue;
            }
            if b.get(i + 2) == Some(&'\'') && b.get(i + 1) != Some(&'\'') {
                // 'x' — a one-char literal (this is what hides '"')
                out.push_str("   ");
                i += 3;
                continue;
            }
            // lifetime — pass through
        }
        out.push(c);
        i += 1;
    }
    out
}

/// `line` contains `word` with identifier boundaries on both sides.
fn has_word(line: &str, word: &str) -> bool {
    let mut from = 0usize;
    while let Some(p) = line[from..].find(word) {
        let p = from + p;
        let before_ok = line[..p]
            .chars()
            .next_back()
            .is_none_or(|c| !(c.is_alphanumeric() || c == '_'));
        let after_ok = line[p + word.len()..]
            .chars()
            .next()
            .is_none_or(|c| !(c.is_alphanumeric() || c == '_'));
        if before_ok && after_ok {
            return true;
        }
        from = p + word.len();
    }
    false
}

/// A `marker` comment is "adjacent" to line `i` (0-indexed) when it
/// appears on the line itself, within the previous `window` lines, or
/// anywhere in the contiguous `//`-comment block directly above —
/// long SAFETY arguments should not be truncated to fit a window.
fn adjacent_marker(raw: &[&str], i: usize, marker: &str, window: usize) -> bool {
    let lo = i.saturating_sub(window);
    if raw[lo..=i].iter().any(|l| l.contains(marker)) {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let t = raw[j].trim_start();
        if t.starts_with("//") {
            if t.contains(marker) {
                return true;
            }
        } else {
            break;
        }
    }
    false
}

/// Lint one file's source. `rel` is the path reported in findings and
/// matched against the allowlist (forward slashes).
pub fn check_source(rel: &str, src: &str) -> Vec<Violation> {
    let scrubbed = scrub(src);
    let code: Vec<&str> = scrubbed.lines().collect();
    let raw: Vec<&str> = src.lines().collect();
    // everything from the first test-gated attribute down is test code
    let test_start = code
        .iter()
        .position(|l| l.contains("#[cfg(test)]") || l.contains("#[cfg(all(test"))
        .unwrap_or(code.len());
    let sync_exempt = STD_SYNC_EXEMPT.iter().any(|e| rel.ends_with(e));
    let mut out = Vec::new();
    let mut push = |line: usize, rule: &'static str, msg: String| {
        out.push(Violation { file: rel.to_string(), line: line + 1, rule, msg });
    };
    for (i, l) in code.iter().enumerate() {
        if has_word(l, "unsafe") && !adjacent_marker(&raw, i, "SAFETY:", 12) {
            push(i, RULE_UNSAFE, "`unsafe` without an adjacent `// SAFETY:` comment".into());
        }
        if l.contains("static mut") {
            push(i, RULE_STATIC_MUT, "`static mut` is banned (use atomics or OnceLock)".into());
        }
        if !sync_exempt && l.contains("std::sync") {
            push(
                i,
                RULE_STD_SYNC,
                "direct `std::sync` use outside the facade; import `crate::util::sync` \
                 so the model-check build covers this site"
                    .into(),
            );
        }
        if i < test_start {
            if l.contains(".unwrap()") || l.contains(".expect(") {
                push(
                    i,
                    RULE_UNWRAP,
                    "`.unwrap()`/`.expect()` in runtime code; return an error instead".into(),
                );
            }
            let weak = ["Ordering::Relaxed", "Ordering::Acquire", "Ordering::Release", "Ordering::AcqRel"];
            if weak.iter().any(|w| l.contains(w)) && !adjacent_marker(&raw, i, "ORDERING:", 6) {
                push(
                    i,
                    RULE_ORDERING,
                    "non-SeqCst atomic ordering without an adjacent `// ORDERING:` \
                     justification"
                        .into(),
                );
            }
        }
    }
    out
}

/// One allowlist entry: suppress `rule` findings in the file whose
/// path ends with `path`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AllowEntry {
    pub rule: String,
    pub path: String,
    pub line: usize,
}

/// Parse `lint.allow`: one `rule path` pair per line, `#` comments and
/// blank lines skipped.
pub fn parse_allowlist(text: &str) -> Result<Vec<AllowEntry>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        match (it.next(), it.next(), it.next()) {
            (Some(rule), Some(path), None) => {
                out.push(AllowEntry { rule: rule.to_string(), path: path.to_string(), line: i + 1 })
            }
            _ => return Err(format!("lint.allow:{}: expected `rule path`, got '{line}'", i + 1)),
        }
    }
    Ok(out)
}

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<std::io::Result<_>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rs_files(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `src_root`, applying the allowlist at
/// `allow_path` (absent file = empty allowlist). Returns the surviving
/// violations — including a `stale-allow` finding for every allowlist
/// entry that no longer suppresses anything.
pub fn run(src_root: &Path, allow_path: &Path) -> Result<Vec<Violation>, String> {
    let allow = match fs::read_to_string(allow_path) {
        Ok(text) => parse_allowlist(&text)?,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        Err(e) => return Err(format!("{}: {e}", allow_path.display())),
    };
    let mut files = Vec::new();
    rs_files(src_root, &mut files).map_err(|e| format!("{}: {e}", src_root.display()))?;
    let mut used = vec![false; allow.len()];
    let mut out = Vec::new();
    for path in &files {
        let src = fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        let rel = path.to_string_lossy().replace('\\', "/");
        for v in check_source(&rel, &src) {
            let suppressed = allow.iter().enumerate().any(|(k, a)| {
                let hit = a.rule == v.rule && v.file.ends_with(&a.path);
                if hit {
                    used[k] = true;
                }
                hit
            });
            if !suppressed {
                out.push(v);
            }
        }
    }
    for (k, a) in allow.iter().enumerate() {
        if !used[k] {
            out.push(Violation {
                file: allow_path.to_string_lossy().into_owned(),
                line: a.line,
                rule: RULE_STALE_ALLOW,
                msg: format!("entry `{} {}` no longer suppresses anything — remove it", a.rule, a.path),
            });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrub_blanks_strings_comments_chars() {
        let src = "let a = \"std::sync\"; // std::sync here\nlet q = '\"'; /* unsafe */ let b = r#\"static mut\"#;";
        let s = scrub(src);
        assert!(!s.contains("std::sync"), "scrubbed: {s}");
        assert!(!s.contains("unsafe"));
        assert!(!s.contains("static mut"));
        assert_eq!(s.lines().count(), src.lines().count(), "newlines preserved");
        // the char literal's quote must not open a string
        assert!(s.contains("let b ="));
    }

    #[test]
    fn scrub_keeps_lifetimes_and_nested_comments() {
        let src = "fn f<'a>(x: &'a str) {} /* outer /* unsafe inner */ still comment */ let y = 1;";
        let s = scrub(src);
        assert!(s.contains("fn f<'a>(x: &'a str)"));
        assert!(!s.contains("unsafe"));
        assert!(s.contains("let y = 1;"));
    }

    #[test]
    fn unsafe_needs_safety_comment() {
        let bad = "fn f() { unsafe { g() } }\n";
        let v = check_source("x.rs", bad);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, RULE_UNSAFE);
        assert_eq!(v[0].line, 1);
        let good = "// SAFETY: g upholds the invariant\nfn f() { unsafe { g() } }\n";
        assert!(check_source("x.rs", good).is_empty());
        // long contiguous comment blocks count as adjacent
        let mut long = String::from("// SAFETY: a very long argument\n");
        for _ in 0..20 {
            long.push_str("// ...continued\n");
        }
        long.push_str("fn f() { unsafe { g() } }\n");
        assert!(check_source("x.rs", &long).is_empty());
    }

    #[test]
    fn unsafe_word_boundary() {
        // attribute names embedding `unsafe` are not the keyword
        let src = "#![deny(unsafe_op_in_unsafe_fn)]\n";
        assert!(check_source("x.rs", src).is_empty());
    }

    #[test]
    fn static_mut_banned() {
        let v = check_source("x.rs", "static mut X: u32 = 0;\n");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, RULE_STATIC_MUT);
    }

    #[test]
    fn unwrap_banned_outside_tests() {
        let src = "fn f() { x.unwrap(); }\nfn g() { y.expect(\"boom\"); }\n#[cfg(test)]\nmod t { fn h() { z.unwrap(); } }\n";
        let v = check_source("x.rs", src);
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().all(|v| v.rule == RULE_UNWRAP));
        // unwrap_or_else is not unwrap
        assert!(check_source("x.rs", "fn f() { x.unwrap_or_else(|e| e.into_inner()); }\n").is_empty());
    }

    #[test]
    fn weak_orderings_need_justification() {
        let bad = "fn f() { a.load(Ordering::Relaxed); }\n";
        let v = check_source("x.rs", bad);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, RULE_ORDERING);
        let good = "// ORDERING: Relaxed — pure counter\nfn f() { a.load(Ordering::Relaxed); }\n";
        assert!(check_source("x.rs", good).is_empty());
        // SeqCst needs no argument
        assert!(check_source("x.rs", "fn f() { a.load(Ordering::SeqCst); }\n").is_empty());
    }

    #[test]
    fn std_sync_only_in_facade_and_checker() {
        let src = "use std::sync::Mutex;\n";
        let v = check_source("rust/src/net/worker.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, RULE_STD_SYNC);
        assert!(check_source("rust/src/util/sync.rs", src).is_empty());
        assert!(check_source("rust/src/util/chk.rs", src).is_empty());
    }

    #[test]
    fn allowlist_roundtrip_and_errors() {
        let allow = parse_allowlist("# comment\n\nunwrap rust/src/main.rs\n").unwrap();
        assert_eq!(allow.len(), 1);
        assert_eq!(allow[0].rule, "unwrap");
        assert_eq!(allow[0].path, "rust/src/main.rs");
        assert!(parse_allowlist("too many words here\n").is_err());
    }
}
