//! Reachability rule passes for `stlt lint --deep`: the call-graph
//! tier that enforces the invariants the paper's O(S)-per-token claim
//! and the repo's bitwise tests rest on.
//!
//! **Hot-path purity.** From the declared roots — every
//! `Mixer::token_step` impl, `decode_step_batch`, the scheduler's
//! `feed_wave`/`decode_wave`, `wire::Frame::{encode,decode}` and
//! `scatter_rows` — flag reachable heap allocation, blocking
//! operations (facade lock acquisition, condvar/channel waits, file
//! or socket I/O) and panic sites (`panic!`-family macros, asserts,
//! and an `[`-after-ident slice-indexing heuristic scoped to `net/`
//! and `coordinator/`, where index arithmetic runs on externally
//! sized data). `.unwrap()`/`.expect(` are *not* re-flagged here: the
//! shallow tier already bans them crate-wide.
//!
//! Two traversal policies keep the ledger honest without drowning it:
//! edges into `src/obs/` are cut (observability has its own overhead
//! budget and bench row), and the *alloc* rule cuts the wave roots
//! (`feed_wave`/`decode_wave`) at the `runtime/` boundary — per-wave
//! workspace inside the engine is covered by the `decode_step_batch`
//! root directly, with its own rationale'd entries, while the wave
//! roots police the scheduler tier where scratch must be reused.
//!
//! **Determinism.** From the same roots: no `HashMap`/`HashSet`
//! iteration (hash order would feed numerics or wire bytes), and no
//! `Instant::now`/`SystemTime` reads (wall clock reaching tensor
//! math). Independently of reachability, any function tagged
//! `// F64-REDUCE` must not `+=`-accumulate in f32 — the scheduler's
//! NLL sums and trainer reductions pin their bits to f64 accumulation.
//!
//! **Panic escape hatch.** A `// PANIC-OK: <invariant>` comment on
//! (or in the comment block above) a flagged line suppresses the
//! panic finding — but only with a non-empty invariant argument; a
//! bare marker is itself a finding.
//!
//! Everything else lands in `lint_deep.allow`, one
//! `rule qual-suffix -- rationale` line per entry; entries are matched
//! by (rule, function-qual suffix) — not line numbers, so refactors
//! within a function do not churn the ledger — and stale entries fail.

use std::collections::{BTreeSet, VecDeque};
use std::fs;
use std::path::Path;

use super::graph::{self, CallGraph};
use super::locks;
use super::parse;
use super::Violation;

pub const RULE_HOT_ALLOC: &str = "hot-alloc";
pub const RULE_HOT_BLOCK: &str = "hot-block";
pub const RULE_HOT_PANIC: &str = "hot-panic";
pub const RULE_DET_HASH: &str = "det-hash-iter";
pub const RULE_DET_TIME: &str = "det-time";
pub const RULE_DET_F32: &str = "det-f32-accum";
pub const RULE_STALE_DEEP: &str = "stale-deep-allow";

/// Heap-allocation sinks. `.clone()` deliberately includes `Arc`
/// clones (an atomic RMW on the hot path is still worth a stated
/// reason); `Arc::clone(` is the idiomatic spelling and is matched by
/// its own pattern below.
const ALLOC_SINKS: &[&str] = &[
    "Vec::new(",
    "Vec::with_capacity(",
    "vec!",
    ".to_vec()",
    ".clone()",
    "Arc::clone(",
    "Box::new(",
    "format!(",
    "String::from(",
    "String::new(",
    "String::with_capacity(",
    ".to_string()",
    ".collect()",
    ".collect::<",
];

/// Blocking sinks: facade lock/condvar/channel waits and file/socket
/// I/O. `.send(`/`.read(`/`.write(` are excluded — the crate's
/// bounded-queue sends are non-blocking by protocol and flagged
/// instead by the lock acquisitions around them.
const BLOCK_SINKS: &[&str] = &[
    ".lock()",
    ".wait(",
    ".wait_timeout(",
    ".wait_while(",
    ".recv()",
    ".recv_timeout(",
    ".join()",
    "TcpStream::",
    "TcpListener::",
    "UdpSocket::",
    "File::",
    "OpenOptions::",
    "read_to_string(",
    "println!(",
    "eprintln!(",
];

/// Panic sinks; `debug_assert!` is excluded by the identifier-boundary
/// check (compiled out of release builds), `.unwrap()`/`.expect(` by
/// the shallow tier's crate-wide ban.
const PANIC_SINKS: &[&str] = &[
    "panic!(",
    "unreachable!(",
    "todo!(",
    "unimplemented!(",
    "assert!(",
    "assert_eq!(",
    "assert_ne!(",
];

const TIME_SINKS: &[&str] = &["Instant::now(", "SystemTime::"];

/// Hash-iteration method suffixes checked against each file's
/// `HashMap`/`HashSet`-declared idents.
const HASH_ITER_SUFFIXES: &[&str] = &[
    ".iter()",
    ".iter_mut()",
    ".into_iter()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".drain(",
    ".retain(",
];

/// One pre-allowlist finding: the function qual is what allowlist
/// entries match against.
struct Finding {
    qual: String,
    v: Violation,
}

/// A declared hot-path root. `wave` roots cut the alloc traversal at
/// the `runtime/` boundary (see module docs).
struct Root {
    node: usize,
    wave: bool,
}

fn hot_roots(g: &CallGraph) -> Vec<Root> {
    let mut out = Vec::new();
    for n in 0..g.nodes.len() {
        let it = g.item(n);
        let is_root = match it.name.as_str() {
            "token_step" => it.trait_name.as_deref() == Some("Mixer"),
            "decode_step_batch" | "feed_wave" | "decode_wave" | "scatter_rows" => true,
            "encode" | "decode" => it.self_ty.as_deref() == Some("Frame"),
            _ => false,
        };
        if is_root {
            let wave = matches!(it.name.as_str(), "feed_wave" | "decode_wave");
            out.push(Root { node: n, wave });
        }
    }
    out
}

/// Files the traversal never descends into: observability (own
/// overhead budget, pinned by its bench row) and the model checker
/// (compiled only under `--cfg model_check`).
fn cut_file(rel: &str) -> bool {
    rel.contains("/obs/") || rel.ends_with("util/chk.rs")
}

/// BFS bookkeeping: for each reached node, the root it was first
/// reached from and its BFS parent (parent == node for roots).
struct Reach {
    info: std::collections::BTreeMap<usize, (usize, usize)>,
    order: Vec<usize>,
}

fn bfs(g: &CallGraph, starts: &[usize], cut: &dyn Fn(&CallGraph, usize) -> bool) -> Reach {
    let mut info = std::collections::BTreeMap::new();
    let mut order = Vec::new();
    let mut q = VecDeque::new();
    for &r in starts {
        if !info.contains_key(&r) {
            info.insert(r, (r, r));
            order.push(r);
            q.push_back(r);
        }
    }
    while let Some(u) = q.pop_front() {
        let root = info[&u].0;
        for &(v, _) in &g.edges[u] {
            if info.contains_key(&v) || cut(g, v) {
                continue;
            }
            info.insert(v, (root, u));
            order.push(v);
            q.push_back(v);
        }
    }
    Reach { info, order }
}

/// Human-readable origin of a reached node: the root, or the BFS path
/// from it (middle elided past 4 hops).
fn origin(g: &CallGraph, reach: &Reach, n: usize) -> String {
    let (root, _) = reach.info[&n];
    if root == n {
        return "a declared hot-path root".to_string();
    }
    let mut path = vec![n];
    let mut cur = n;
    while let Some(&(_, p)) = reach.info.get(&cur) {
        if p == cur {
            break;
        }
        path.push(p);
        cur = p;
    }
    path.reverse();
    let names: Vec<&str> = path.iter().map(|&x| g.item(x).qual.as_str()).collect();
    let via = if names.len() <= 4 {
        names.join(" -> ")
    } else {
        format!("{} -> {} -> ... -> {}", names[0], names[1], names[names.len() - 2])
    };
    format!("reachable from `{}` via {via}", g.item(root).qual)
}

/// `pat` occurs in `line` with an identifier boundary before it (so
/// `debug_assert!(` never matches `assert!(`, `MyVec::new(` never
/// matches `Vec::new(`).
fn find_sink(line: &str, pat: &str) -> bool {
    let first = match pat.chars().next() {
        Some(c) => c,
        None => return false,
    };
    let needs_boundary = first.is_alphanumeric() || first == '_';
    let mut from = 0usize;
    while let Some(p) = line[from..].find(pat) {
        let at = from + p;
        let bounded = !needs_boundary
            || line[..at]
                .chars()
                .next_back()
                .is_none_or(|c| !(c.is_alphanumeric() || c == '_'));
        if bounded {
            return true;
        }
        from = at + pat.len();
    }
    false
}

/// `[` directly after an identifier char, `)` or `]` — the slice
/// indexing / range-slicing shapes that can panic at run time.
fn has_indexing(line: &str) -> bool {
    let mut prev = ' ';
    for c in line.chars() {
        if c == '['
            && (prev.is_alphanumeric() || prev == '_' || prev == ')' || prev == ']')
        {
            return true;
        }
        prev = c;
    }
    false
}

/// Indexing is only flagged where index arithmetic runs on externally
/// sized data; kernel code indexes its own workspaces pervasively and
/// is covered by the shape checks at its entry points.
fn indexing_in_scope(rel: &str) -> bool {
    rel.contains("/net/") || rel.contains("/coordinator/")
}

/// `// PANIC-OK: <invariant>` on the line or in the contiguous comment
/// block above. `Some(rationale)` when a marker is present (possibly
/// empty — the caller flags that).
fn panic_ok_rationale(raw: &[&str], i: usize) -> Option<String> {
    let find = |l: &str| l.find("PANIC-OK:").map(|p| l[p + "PANIC-OK:".len()..].trim().to_string());
    if let Some(r) = raw.get(i).and_then(|l| find(l)) {
        return Some(r);
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let t = raw[j].trim_start();
        if !t.starts_with("//") {
            break;
        }
        if let Some(r) = find(t) {
            return Some(r);
        }
    }
    None
}

fn push_finding(out: &mut Vec<Finding>, g: &CallGraph, n: usize, line: usize, rule: &'static str, msg: String) {
    out.push(Finding {
        qual: g.item(n).qual.clone(),
        v: Violation { file: g.file_of(n).rel.clone(), line: line + 1, rule, msg },
    });
}

/// Hot-path purity: one full-rules traversal (block + panic), plus a
/// per-root alloc traversal so wave roots can cut at `runtime/`.
fn hot_pass(g: &CallGraph, out: &mut Vec<Finding>) {
    let roots = hot_roots(g);
    let all: Vec<usize> = roots.iter().map(|r| r.node).collect();
    let full = bfs(g, &all, &|g, v| cut_file(&g.file_of(v).rel));
    for &n in &full.order {
        let o = origin(g, &full, n);
        scan_hot_node(g, n, &o, false, true, out);
    }
    // alloc: per-root so the cut can depend on the root kind, first
    // reach wins (deterministic: roots iterate in node order)
    let mut alloc_seen: BTreeSet<usize> = BTreeSet::new();
    for r in &roots {
        let cut = |g: &CallGraph, v: usize| {
            let rel = &g.file_of(v).rel;
            cut_file(rel) || (r.wave && rel.contains("/runtime/"))
        };
        let reach = bfs(g, &[r.node], &cut);
        for &n in &reach.order {
            if !alloc_seen.insert(n) {
                continue;
            }
            let o = origin(g, &reach, n);
            scan_hot_node(g, n, &o, true, false, out);
        }
    }
}

/// Scan one reached node's body for hot-path sinks. `alloc` and
/// `rest` (block + panic) are split because they ride different
/// traversals.
fn scan_hot_node(
    g: &CallGraph,
    n: usize,
    origin: &str,
    alloc: bool,
    rest: bool,
    out: &mut Vec<Finding>,
) {
    let f = g.file_of(n);
    let it = g.item(n);
    let code: Vec<&str> = f.scrubbed.lines().collect();
    let raw: Vec<&str> = f.raw.lines().collect();
    let idx_scope = indexing_in_scope(&f.rel);
    let hi = it.end_line.min(code.len().saturating_sub(1));
    for i in it.start_line..=hi {
        let l = code[i];
        if alloc {
            if let Some(pat) = ALLOC_SINKS.iter().find(|p| find_sink(l, p)) {
                let what = pat.trim_end_matches('(');
                push_finding(
                    out,
                    g,
                    n,
                    i,
                    RULE_HOT_ALLOC,
                    format!("`{what}` allocates in `{}`, {origin}", it.qual),
                );
            }
        }
        if !rest {
            continue;
        }
        if let Some(pat) = BLOCK_SINKS.iter().find(|p| find_sink(l, p)) {
            let what = pat.trim_end_matches('(');
            push_finding(
                out,
                g,
                n,
                i,
                RULE_HOT_BLOCK,
                format!("`{what}` can block in `{}`, {origin}", it.qual),
            );
        }
        let panic_pat = PANIC_SINKS.iter().find(|p| find_sink(l, p));
        let indexed = idx_scope && has_indexing(l) && i != it.start_line;
        if panic_pat.is_some() || indexed {
            match panic_ok_rationale(&raw, i) {
                Some(r) if !r.is_empty() => {}
                Some(_) => push_finding(
                    out,
                    g,
                    n,
                    i,
                    RULE_HOT_PANIC,
                    "`PANIC-OK` marker without an invariant argument — state why this \
                     cannot panic"
                        .to_string(),
                ),
                None => {
                    let what = match panic_pat {
                        Some(p) => format!("`{}`", p.trim_end_matches('(')),
                        None => "slice indexing".to_string(),
                    };
                    push_finding(
                        out,
                        g,
                        n,
                        i,
                        RULE_HOT_PANIC,
                        format!(
                            "{what} can panic in `{}`, {origin} — use checked access or \
                             add `// PANIC-OK: <invariant>`",
                            it.qual
                        ),
                    );
                }
            }
        }
    }
}

/// Determinism: hash-order iteration and wall-clock reads reachable
/// from the hot roots.
fn det_pass(g: &CallGraph, out: &mut Vec<Finding>) {
    let roots: Vec<usize> = hot_roots(g).iter().map(|r| r.node).collect();
    let reach = bfs(g, &roots, &|g, v| cut_file(&g.file_of(v).rel));
    for &n in &reach.order {
        let f = g.file_of(n);
        let it = g.item(n);
        let o = origin(g, &reach, n);
        let code: Vec<&str> = f.scrubbed.lines().collect();
        let hashes = &g.hash_idents[g.nodes[n].0];
        let hi = it.end_line.min(code.len().saturating_sub(1));
        for i in it.start_line..=hi {
            let l = code[i];
            if let Some(pat) = TIME_SINKS.iter().find(|p| find_sink(l, p)) {
                let what = pat.trim_end_matches(['(', ':']);
                push_finding(
                    out,
                    g,
                    n,
                    i,
                    RULE_DET_TIME,
                    format!(
                        "`{what}` wall-clock read in `{}`, {o} — time must not feed \
                         tensor math or wire bytes",
                        it.qual
                    ),
                );
            }
            if let Some(h) = hashes.iter().find(|h| hash_iterated(l, h)) {
                push_finding(
                    out,
                    g,
                    n,
                    i,
                    RULE_DET_HASH,
                    format!(
                        "hash-order iteration over `{h}` in `{}`, {o} — order is \
                         nondeterministic; use a BTreeMap/sorted keys",
                        it.qual
                    ),
                );
            }
        }
    }
}

/// `line` iterates the hash-typed ident `h`: `h.iter()`-style method
/// suffixes or a `for … in … h` loop header.
fn hash_iterated(line: &str, h: &str) -> bool {
    for suf in HASH_ITER_SUFFIXES {
        let pat = format!("{h}{suf}");
        if find_sink(line, &pat) {
            return true;
        }
    }
    if let Some(p) = line.find("for ") {
        if let Some(q) = line[p..].find(" in ") {
            return super::has_word(&line[p + q + 4..], h);
        }
    }
    false
}

/// `// F64-REDUCE` functions must not `+=`-accumulate in f32: flag
/// `+=` lines whose left-hand ident is declared `f32` in the file or
/// whose right side rounds through `as f32`.
fn f64_reduce_pass(g: &CallGraph, out: &mut Vec<Finding>) {
    for n in 0..g.nodes.len() {
        let f = g.file_of(n);
        let it = g.item(n);
        let raw: Vec<&str> = f.raw.lines().collect();
        let lo = it.start_line.saturating_sub(3);
        let tagged = raw[lo..=it.start_line.min(raw.len().saturating_sub(1))]
            .iter()
            .any(|l| l.contains("F64-REDUCE"));
        if !tagged {
            continue;
        }
        let code: Vec<&str> = f.scrubbed.lines().collect();
        let floats = &g.f32_idents[g.nodes[n].0];
        let hi = it.end_line.min(code.len().saturating_sub(1));
        for i in it.start_line..=hi {
            let l = code[i];
            let Some(p) = l.find("+=") else { continue };
            let lhs: String = l[..p]
                .trim_end()
                .chars()
                .rev()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect::<String>()
                .chars()
                .rev()
                .collect();
            if l.contains(" as f32") || floats.contains(&lhs) {
                push_finding(
                    out,
                    g,
                    n,
                    i,
                    RULE_DET_F32,
                    format!(
                        "f32 `+=` accumulation in `{}`, a `// F64-REDUCE` function — \
                         accumulate in f64 and round once at the edge",
                        it.qual
                    ),
                );
            }
        }
    }
}

/// One `lint_deep.allow` entry: suppress `rule` findings in functions
/// whose qualified path ends with `path`. The rationale is mandatory.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeepAllowEntry {
    pub rule: String,
    pub path: String,
    pub rationale: String,
    pub line: usize,
}

/// Parse `lint_deep.allow`: one `rule qual-suffix -- rationale` line
/// per entry, `#` comments and blank lines skipped.
pub fn parse_deep_allowlist(text: &str) -> Result<Vec<DeepAllowEntry>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (head, rationale) = line.split_once(" -- ").ok_or_else(|| {
            format!(
                "lint_deep.allow:{}: expected `rule qual-suffix -- rationale`, got '{line}'",
                i + 1
            )
        })?;
        let rationale = rationale.trim();
        if rationale.is_empty() {
            return Err(format!("lint_deep.allow:{}: empty rationale", i + 1));
        }
        let mut it = head.split_whitespace();
        match (it.next(), it.next(), it.next()) {
            (Some(rule), Some(path), None) => out.push(DeepAllowEntry {
                rule: rule.to_string(),
                path: path.to_string(),
                rationale: rationale.to_string(),
                line: i + 1,
            }),
            _ => {
                return Err(format!(
                    "lint_deep.allow:{}: expected `rule qual-suffix -- rationale`, got '{line}'",
                    i + 1
                ))
            }
        }
    }
    Ok(out)
}

fn qual_matches(qual: &str, path: &str) -> bool {
    qual == path || qual.ends_with(&format!("::{path}"))
}

/// Run every deep pass over the `.rs` files under `src_root`, apply
/// the allowlist at `allow_path` (absent file = empty), and — when
/// `lock_graph_out` is given — write the lock-order graph JSON there.
/// Stale allowlist entries are violations, mirroring the shallow tier.
pub fn run_deep(
    src_root: &Path,
    allow_path: &Path,
    lock_graph_out: Option<&Path>,
) -> Result<Vec<Violation>, String> {
    let allow = match fs::read_to_string(allow_path) {
        Ok(text) => parse_deep_allowlist(&text)?,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        Err(e) => return Err(format!("{}: {e}", allow_path.display())),
    };
    let mut paths = Vec::new();
    super::rs_files(src_root, &mut paths).map_err(|e| format!("{}: {e}", src_root.display()))?;
    let mut parsed = Vec::new();
    for p in &paths {
        let src = fs::read_to_string(p).map_err(|e| format!("{}: {e}", p.display()))?;
        let rel = p.to_string_lossy().replace('\\', "/");
        parsed.push(parse::parse_file(&rel, &src));
    }
    let g = graph::build(parsed);
    let mut findings = Vec::new();
    hot_pass(&g, &mut findings);
    det_pass(&g, &mut findings);
    f64_reduce_pass(&g, &mut findings);
    let lg = locks::analyze(&g);
    if let Some(out_path) = lock_graph_out {
        fs::write(out_path, lg.to_json()).map_err(|e| format!("{}: {e}", out_path.display()))?;
    }
    findings.extend(lg.cycle_findings().into_iter().map(|(qual, v)| Finding { qual, v }));
    let mut used = vec![false; allow.len()];
    let mut out = Vec::new();
    for f in findings {
        let suppressed = allow.iter().enumerate().any(|(k, a)| {
            let hit = a.rule == f.v.rule && qual_matches(&f.qual, &a.path);
            if hit {
                used[k] = true;
            }
            hit
        });
        if !suppressed {
            out.push(f.v);
        }
    }
    for (k, a) in allow.iter().enumerate() {
        if !used[k] {
            out.push(Violation {
                file: allow_path.to_string_lossy().into_owned(),
                line: a.line,
                rule: RULE_STALE_DEEP,
                msg: format!(
                    "entry `{} {}` no longer suppresses anything — remove it",
                    a.rule, a.path
                ),
            });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::parse::parse_file;
    use super::*;

    fn findings_of(sources: &[(&str, &str)]) -> Vec<(String, &'static str, String)> {
        let g = graph::build(sources.iter().map(|(rel, src)| parse_file(rel, src)).collect());
        let mut out = Vec::new();
        hot_pass(&g, &mut out);
        det_pass(&g, &mut out);
        f64_reduce_pass(&g, &mut out);
        out.into_iter().map(|f| (f.qual, f.v.rule, f.v.msg)).collect()
    }

    #[test]
    fn alloc_reachable_from_root_is_flagged() {
        let src = "\
impl T {
    pub fn feed_wave(&self) {
        helper();
    }
}
fn helper() {
    let v = Vec::new();
}
";
        let f = findings_of(&[("src/coordinator/server.rs", src)]);
        let hit = f
            .iter()
            .find(|(q, r, _)| *r == RULE_HOT_ALLOC && q.ends_with("::helper"))
            .expect("alloc finding");
        assert!(hit.2.contains("feed_wave"), "origin chain named: {}", hit.2);
    }

    #[test]
    fn unreachable_fns_are_not_scanned() {
        let src = "\
pub fn feed_wave() {}
fn cold() {
    let v = Vec::new();
    let g = m.lock();
}
";
        let f = findings_of(&[("src/coordinator/server.rs", src)]);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn wave_roots_cut_alloc_at_runtime_boundary() {
        let sched = "\
pub fn feed_wave() {
    crate::runtime::exec::engine_step();
}
";
        let engine = "\
pub fn engine_step() {
    let v = Vec::new();
    let g = m.lock();
}
";
        let f = findings_of(&[
            ("src/coordinator/server.rs", sched),
            ("src/runtime/exec.rs", engine),
        ]);
        // alloc is cut at the runtime/ boundary for wave roots…
        assert!(
            !f.iter().any(|(q, r, _)| *r == RULE_HOT_ALLOC && q.ends_with("engine_step")),
            "{f:?}"
        );
        // …but blocking is still traversed through it
        assert!(
            f.iter().any(|(q, r, _)| *r == RULE_HOT_BLOCK && q.ends_with("engine_step")),
            "{f:?}"
        );
    }

    #[test]
    fn decode_step_batch_root_covers_engine_allocs() {
        let engine = "\
impl Engine {
    pub fn decode_step_batch(&self) {
        let mut x = vec![0.0f32; 8];
    }
}
";
        let f = findings_of(&[("src/runtime/native_stlt.rs", engine)]);
        assert!(
            f.iter().any(|(q, r, _)| *r == RULE_HOT_ALLOC && q.ends_with("decode_step_batch")),
            "{f:?}"
        );
    }

    #[test]
    fn obs_edges_are_cut() {
        let sched = "\
pub fn decode_wave() {
    crate::obs::metrics::bump();
}
";
        let obs = "\
pub fn bump() {
    let v = Vec::new();
}
";
        let f = findings_of(&[("src/coordinator/server.rs", sched), ("src/obs/metrics.rs", obs)]);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn panic_sites_and_indexing_with_panic_ok_markers() {
        let src = "\
pub fn feed_wave(xs: &[f32], i: usize) {
    let a = xs[i];
    // PANIC-OK: i < xs.len() checked by the wave assembler
    let b = xs[i];
    // PANIC-OK:
    let c = xs[i];
    assert!(i < 4);
}
";
        let f = findings_of(&[("src/coordinator/server.rs", src)]);
        let panics: Vec<_> = f.iter().filter(|(_, r, _)| *r == RULE_HOT_PANIC).collect();
        // line 2 indexing (unmarked), line 6 empty marker, line 7 assert
        assert_eq!(panics.len(), 3, "{panics:?}");
        assert!(panics.iter().any(|(_, _, m)| m.contains("slice indexing")));
        assert!(panics.iter().any(|(_, _, m)| m.contains("without an invariant")));
        assert!(panics.iter().any(|(_, _, m)| m.contains("`assert!`")));
    }

    #[test]
    fn debug_assert_and_indexing_scope_are_exempt() {
        let src = "\
pub fn token_step(xs: &[f32], i: usize) {
    debug_assert!(i < xs.len());
    let a = xs[i];
}
";
        // runtime/ file: indexing heuristic out of scope, debug_assert
        // bounded away from assert!; the Mixer impl context makes
        // token_step a root
        let src2 = format!("pub trait Mixer {{}}\nimpl Mixer for R {{\n{src}}}\n");
        let f = findings_of(&[("src/runtime/mixer.rs", &src2)]);
        assert!(f.iter().all(|(_, r, _)| *r != RULE_HOT_PANIC), "{f:?}");
    }

    #[test]
    fn det_rules_flag_time_and_hash_iteration() {
        let src = "\
use std::collections::HashMap;
pub struct S { sessions: HashMap<u64, u32> }
impl S {
    pub fn decode_wave(&self) {
        let t = Instant::now();
        for (k, v) in self.sessions.iter() {
        }
    }
}
";
        let f = findings_of(&[("src/coordinator/server.rs", src)]);
        assert!(f.iter().any(|(_, r, _)| *r == RULE_DET_TIME), "{f:?}");
        assert!(f.iter().any(|(_, r, m)| *r == RULE_DET_HASH && m.contains("sessions")), "{f:?}");
    }

    #[test]
    fn f64_reduce_tag_bans_f32_accumulation() {
        let src = "\
// F64-REDUCE: per-session NLL sums are bit-pinned
pub fn tally(xs: &[f32], acc: &mut f32) {
    for x in xs {
        *acc += x;
    }
}
pub fn untagged(xs: &[f32], acc: &mut f32) {
    for x in xs {
        *acc += x;
    }
}
";
        let f = findings_of(&[("src/coordinator/server.rs", src)]);
        let hits: Vec<_> = f.iter().filter(|(_, r, _)| *r == RULE_DET_F32).collect();
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert!(hits[0].0.ends_with("::tally"));
    }

    #[test]
    fn deep_allowlist_parses_and_requires_rationale() {
        let ok = parse_deep_allowlist(
            "# ledger\nhot-alloc Engine::decode_step_batch -- per-wave workspace, amortized\n",
        )
        .unwrap();
        assert_eq!(ok.len(), 1);
        assert_eq!(ok[0].rule, "hot-alloc");
        assert_eq!(ok[0].path, "Engine::decode_step_batch");
        assert_eq!(ok[0].rationale, "per-wave workspace, amortized");
        assert!(parse_deep_allowlist("hot-alloc Engine::step\n").is_err(), "missing rationale");
        assert!(parse_deep_allowlist("hot-alloc Engine::step -- \n").is_err(), "empty rationale");
        assert!(parse_deep_allowlist("one two three -- why\n").is_err(), "extra token");
    }

    #[test]
    fn qual_suffix_matching() {
        assert!(qual_matches("coordinator::server::ModelThread::feed_wave", "feed_wave"));
        assert!(qual_matches(
            "coordinator::server::ModelThread::feed_wave",
            "ModelThread::feed_wave"
        ));
        assert!(!qual_matches("coordinator::server::ModelThread::feed_wave", "wave"));
    }
}
