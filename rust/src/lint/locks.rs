//! Static lock-order graph over the `util::sync` facade — the static
//! complement of the model checker in [`crate::util::chk`], which can
//! only exercise protocols someone hand-ported.
//!
//! A lock is identified as `<file-stem>.<receiver-ident>`: the
//! `self.state.lock()` in `util/threadpool.rs` is `threadpool.state`.
//! An acquisition's *hold region* runs from the acquiring line to the
//! first `drop(<guard>)` of its `let`-bound guard, or to the end of
//! the enclosing block (brace depth), whichever comes first — an
//! over-approximation, never an under-approximation, of the guard's
//! lexical lifetime.
//!
//! Within a region of lock `A`, acquiring `B` directly adds the order
//! edge `A -> B`; calling a function whose transitive lock set
//! contains `B` adds the same edge (fixpoint over call edges). A
//! guard-*returning* helper cannot be seen to acquire for its caller,
//! so it declares itself with `// LINT-LOCK: <name>` next to its
//! header: call sites are then treated as acquisitions of `<name>` in
//! the caller, `let`-binding and all.
//!
//! Same-lock re-acquisition is *not* an edge (a second `.lock()` after
//! an implicit guard drop is indistinguishable statically; reentrancy
//! is the checker's job). The graph is emitted as deterministic JSON
//! (`--lock-graph`), and any cycle is a `lock-cycle` finding whose
//! qual is the sorted lock set joined with `+` — suppressible in
//! `lint_deep.allow` only with a stated reason, like every other rule.

use std::collections::{BTreeMap, BTreeSet};

use super::graph::CallGraph;
use super::Violation;

pub const RULE_LOCK_CYCLE: &str = "lock-cycle";

/// One order edge: while holding `from`, `to` is acquired at
/// `file:line` — directly (`via` = the holding function) or through a
/// call (`via` = the callee whose lock set contains `to`).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct LockEdge {
    pub from: String,
    pub to: String,
    pub via: String,
    pub file: String,
    /// 1-indexed.
    pub line: usize,
}

/// The extracted lock-order graph.
pub struct LockGraph {
    pub locks: BTreeSet<String>,
    pub edges: BTreeSet<LockEdge>,
    /// Each cycle as a lock-name sequence (first element repeated at
    /// the end is implied, not stored), canonicalized and deduped.
    pub cycles: Vec<Vec<String>>,
}

/// One acquisition inside a function body.
struct Acq {
    lock: String,
    /// 0-indexed line within the file.
    line: usize,
    /// Brace depth before the acquiring line (region ends when the
    /// depth drops below this).
    depth: usize,
    guard: Option<String>,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn ident_before(chars: &[char], end: usize) -> String {
    let mut s = end;
    while s > 0 && is_ident(chars[s - 1]) {
        s -= 1;
    }
    chars[s..end].iter().collect()
}

/// `path/to/threadpool.rs` → `threadpool`.
fn file_stem(rel: &str) -> &str {
    let base = rel.rsplit('/').next().unwrap_or(rel);
    base.strip_suffix(".rs").unwrap_or(base)
}

/// `let g = …` / `let mut g = …` on the acquiring line binds the
/// guard; anything else (expression statement, tuple pattern) has no
/// nameable guard and the region runs to the end of the block.
fn guard_binding(line: &str) -> Option<String> {
    let t = line.trim_start();
    let rest = t.strip_prefix("let ")?;
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let g: String = rest.chars().take_while(|c| is_ident(*c)).collect();
    if g.is_empty() {
        None
    } else {
        Some(g)
    }
}

/// Direct `.lock()` acquisitions on one scrubbed line, named by their
/// receiver ident.
fn line_acquisitions(line: &str, stem: &str, depth: usize, line_no: usize) -> Vec<Acq> {
    let chars: Vec<char> = line.chars().collect();
    let mut out = Vec::new();
    let pat: Vec<char> = ".lock()".chars().collect();
    let mut i = 0usize;
    while i + pat.len() <= chars.len() {
        if chars[i..i + pat.len()] != pat[..] {
            i += 1;
            continue;
        }
        let recv = ident_before(&chars, i);
        if !recv.is_empty() {
            out.push(Acq {
                lock: format!("{stem}.{recv}"),
                line: line_no,
                depth,
                guard: guard_binding(line),
            });
        }
        i += pat.len();
    }
    out
}

/// `// LINT-LOCK: name[, name…]` in the function's raw span (header
/// comment block included): the locks a call to this function leaves
/// held in its caller.
fn declared_locks(raw: &[&str], start: usize, end: usize) -> Vec<String> {
    let mut out = Vec::new();
    let mut scan = |l: &str| {
        if let Some(p) = l.find("LINT-LOCK:") {
            for name in l[p + "LINT-LOCK:".len()..].split(',') {
                let name: String =
                    name.trim().chars().take_while(|c| is_ident(*c) || *c == '.').collect();
                if !name.is_empty() {
                    out.push(name);
                }
            }
        }
    };
    let mut j = start;
    while j > 0 {
        j -= 1;
        let t = raw[j].trim_start();
        if !t.starts_with("//") && !t.starts_with("#[") {
            break;
        }
        scan(t);
    }
    for l in raw.iter().take(end.min(raw.len().saturating_sub(1)) + 1).skip(start) {
        scan(l);
    }
    out
}

/// Build the lock-order graph over the whole call graph (test code is
/// already excluded; `util/chk.rs` is skipped — it exists only under
/// `--cfg model_check`).
pub fn analyze(g: &CallGraph) -> LockGraph {
    let skip = |n: usize| g.file_of(n).rel.ends_with("util/chk.rs");
    // -- phase 1: per-node direct acquisitions + LINT-LOCK decls -----
    let n_nodes = g.nodes.len();
    let mut decls: Vec<Vec<String>> = vec![Vec::new(); n_nodes];
    let mut direct: Vec<Vec<Acq>> = Vec::with_capacity(n_nodes);
    for n in 0..n_nodes {
        let f = g.file_of(n);
        let it = g.item(n);
        if skip(n) {
            direct.push(Vec::new());
            continue;
        }
        let code: Vec<&str> = f.scrubbed.lines().collect();
        let raw: Vec<&str> = f.raw.lines().collect();
        let stem = file_stem(&f.rel);
        let hi = it.end_line.min(code.len().saturating_sub(1));
        let mut acqs = Vec::new();
        let mut depth = 0usize;
        for i in it.start_line..=hi {
            acqs.extend(line_acquisitions(code[i], stem, depth, i));
            for c in code[i].chars() {
                match c {
                    '{' => depth += 1,
                    '}' => depth = depth.saturating_sub(1),
                    _ => {}
                }
            }
        }
        decls[n] = declared_locks(&raw, it.start_line, it.end_line);
        direct.push(acqs);
    }
    // -- phase 2: transitive lock sets (fixpoint over call edges) ----
    let mut locks_of: Vec<BTreeSet<String>> = (0..n_nodes)
        .map(|n| {
            direct[n]
                .iter()
                .map(|a| a.lock.clone())
                .chain(decls[n].iter().cloned())
                .collect()
        })
        .collect();
    loop {
        let mut changed = false;
        for n in 0..n_nodes {
            if skip(n) {
                continue;
            }
            for &(t, _) in &g.edges[n] {
                if skip(t) {
                    continue;
                }
                let add: Vec<String> =
                    locks_of[t].difference(&locks_of[n]).cloned().collect();
                if !add.is_empty() {
                    locks_of[n].extend(add);
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }
    // -- phase 3: hold regions → order edges -------------------------
    let mut locks: BTreeSet<String> = BTreeSet::new();
    let mut edges: BTreeSet<LockEdge> = BTreeSet::new();
    for n in 0..n_nodes {
        if skip(n) {
            continue;
        }
        let f = g.file_of(n);
        let it = g.item(n);
        let code: Vec<&str> = f.scrubbed.lines().collect();
        let stem = file_stem(&f.rel);
        let hi = it.end_line.min(code.len().saturating_sub(1));
        // depth before each line, relative to the fn's first line
        let mut depth_before: BTreeMap<usize, usize> = BTreeMap::new();
        let mut d = 0usize;
        for i in it.start_line..=hi {
            depth_before.insert(i, d);
            for c in code[i].chars() {
                match c {
                    '{' => d += 1,
                    '}' => d = d.saturating_sub(1),
                    _ => {}
                }
            }
        }
        // acquisitions seen by the caller: direct ones plus calls to
        // LINT-LOCK helpers
        let mut acqs: Vec<Acq> = Vec::new();
        for a in &direct[n] {
            locks.insert(a.lock.clone());
            acqs.push(Acq {
                lock: a.lock.clone(),
                line: a.line,
                depth: a.depth,
                guard: a.guard.clone(),
            });
        }
        for &(t, line) in &g.edges[n] {
            for l in &decls[t] {
                locks.insert(l.clone());
                acqs.push(Acq {
                    lock: l.clone(),
                    line,
                    depth: depth_before.get(&line).copied().unwrap_or(0),
                    guard: code.get(line).copied().and_then(guard_binding),
                });
            }
        }
        acqs.sort_by_key(|a| a.line);
        for a in &acqs {
            // region end: drop(guard), or depth falling below the
            // acquisition depth. An acquisition with no `let`-bound
            // guard is a temporary: it dies with its statement (or,
            // for an `if let`/`match` scrutinee, with that construct's
            // block), so its region also ends as soon as the depth
            // returns *to* the acquisition depth on a later line.
            let mut end = hi;
            for i in (a.line + 1)..=hi {
                let d = depth_before.get(&i).copied().unwrap_or(0);
                if d < a.depth || (a.guard.is_none() && d <= a.depth) {
                    end = i.saturating_sub(1);
                    break;
                }
                if let Some(gd) = &a.guard {
                    if code[i].contains(&format!("drop({gd})")) {
                        end = i;
                        break;
                    }
                }
            }
            // later direct acquisitions inside the region
            for b in &acqs {
                if b.line > a.line && b.line <= end && b.lock != a.lock {
                    edges.insert(LockEdge {
                        from: a.lock.clone(),
                        to: b.lock.clone(),
                        via: it.qual.clone(),
                        file: f.rel.clone(),
                        line: b.line + 1,
                    });
                }
            }
            // calls inside the region whose transitive set locks more
            for &(t, line) in &g.edges[n] {
                if line <= a.line || line > end || skip(t) {
                    continue;
                }
                for l in &locks_of[t] {
                    if *l != a.lock {
                        locks.insert(l.clone());
                        edges.insert(LockEdge {
                            from: a.lock.clone(),
                            to: l.clone(),
                            via: g.item(t).qual.clone(),
                            file: f.rel.clone(),
                            line: line + 1,
                        });
                    }
                }
            }
        }
    }
    let cycles = find_cycles(&locks, &edges);
    LockGraph { locks, edges, cycles }
}

/// All elementary cycles reachable by DFS back edges, canonicalized
/// (rotated to start at the smallest name) and deduped.
fn find_cycles(locks: &BTreeSet<String>, edges: &BTreeSet<LockEdge>) -> Vec<Vec<String>> {
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for e in edges {
        adj.entry(&e.from).or_default().insert(&e.to);
    }
    let mut found: BTreeSet<Vec<String>> = BTreeSet::new();
    for start in locks {
        let mut on: Vec<&str> = vec![start];
        dfs(start, &adj, &mut on, &mut found);
    }
    found.into_iter().collect()
}

fn dfs<'a>(
    u: &'a str,
    adj: &BTreeMap<&'a str, BTreeSet<&'a str>>,
    on: &mut Vec<&'a str>,
    found: &mut BTreeSet<Vec<String>>,
) {
    // bounded: lock sets are tiny (≤ tens), so a plain path-DFS is fine
    let next: Vec<&str> = adj.get(u).map(|s| s.iter().copied().collect()).unwrap_or_default();
    for v in next {
        if let Some(pos) = on.iter().position(|&x| x == v) {
            let cycle: Vec<String> = on[pos..].iter().map(|s| s.to_string()).collect();
            found.insert(canonical(cycle));
            continue;
        }
        on.push(v);
        dfs(v, adj, on, found);
        on.pop();
    }
}

/// Rotate the cycle to start at its lexicographically smallest name.
fn canonical(mut c: Vec<String>) -> Vec<String> {
    if c.is_empty() {
        return c;
    }
    let min = c.iter().enumerate().min_by_key(|(_, s)| s.as_str()).map(|(i, _)| i).unwrap_or(0);
    c.rotate_left(min);
    c
}

fn json_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl LockGraph {
    /// Deterministic JSON artifact: sorted lock names, sorted edges,
    /// canonicalized cycles.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"locks\": [");
        for (i, l) in self.locks.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            json_str(l, &mut s);
        }
        s.push_str("],\n  \"edges\": [");
        for (i, e) in self.edges.iter().enumerate() {
            s.push_str(if i > 0 { ",\n    " } else { "\n    " });
            s.push_str("{\"from\": ");
            json_str(&e.from, &mut s);
            s.push_str(", \"to\": ");
            json_str(&e.to, &mut s);
            s.push_str(", \"via\": ");
            json_str(&e.via, &mut s);
            s.push_str(", \"file\": ");
            json_str(&e.file, &mut s);
            s.push_str(&format!(", \"line\": {}}}", e.line));
        }
        if !self.edges.is_empty() {
            s.push_str("\n  ");
        }
        s.push_str("],\n  \"cycles\": [");
        for (i, c) in self.cycles.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push('[');
            for (j, l) in c.iter().enumerate() {
                if j > 0 {
                    s.push_str(", ");
                }
                json_str(l, &mut s);
            }
            s.push(']');
        }
        s.push_str("]\n}\n");
        s
    }

    /// One `(qual, Violation)` per cycle. The qual (sorted lock set
    /// joined with `+`) lets an allowlist entry name a cycle precisely
    /// if suppression is ever justified; the file/line point at one
    /// participating edge.
    pub fn cycle_findings(&self) -> Vec<(String, Violation)> {
        let mut out = Vec::new();
        for c in &self.cycles {
            let mut sorted = c.clone();
            sorted.sort();
            let qual = sorted.join("+");
            let display = {
                let mut d = c.clone();
                d.push(c[0].clone());
                d.join(" -> ")
            };
            let at = self
                .edges
                .iter()
                .find(|e| c.contains(&e.from) && c.contains(&e.to))
                .map(|e| (e.file.clone(), e.line))
                .unwrap_or_else(|| ("<lock-order>".to_string(), 1));
            out.push((
                qual,
                Violation {
                    file: at.0,
                    line: at.1,
                    rule: RULE_LOCK_CYCLE,
                    msg: format!(
                        "lock-order cycle: {display} — two threads taking these in \
                         different orders can deadlock; impose one global order"
                    ),
                },
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::graph::build;
    use super::super::parse::parse_file;
    use super::*;

    fn graph_of(sources: &[(&str, &str)]) -> LockGraph {
        analyze(&build(sources.iter().map(|(rel, src)| parse_file(rel, src)).collect()))
    }

    #[test]
    fn cyclic_fixture_is_deterministically_caught() {
        let src = "\
impl S {
    pub fn ab(&self) {
        let g = self.alpha.lock();
        let h = self.beta.lock();
    }
    pub fn ba(&self) {
        let g = self.beta.lock();
        let h = self.alpha.lock();
    }
}
";
        let lg = graph_of(&[("src/m.rs", src)]);
        assert_eq!(lg.cycles.len(), 1, "{:?}", lg.cycles);
        assert_eq!(lg.cycles[0], vec!["m.alpha".to_string(), "m.beta".to_string()]);
        let f = lg.cycle_findings();
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].0, "m.alpha+m.beta");
        assert_eq!(f[0].1.rule, RULE_LOCK_CYCLE);
        // deterministic: same input, same JSON
        assert_eq!(lg.to_json(), graph_of(&[("src/m.rs", src)]).to_json());
    }

    #[test]
    fn transitive_edges_cross_calls() {
        let src = "\
impl S {
    pub fn outer(&self) {
        let g = self.a.lock();
        self.inner();
    }
    fn inner(&self) {
        let g = self.b.lock();
    }
}
";
        let lg = graph_of(&[("src/m.rs", src)]);
        assert!(lg.cycles.is_empty());
        let e: Vec<_> =
            lg.edges.iter().map(|e| (e.from.as_str(), e.to.as_str(), e.via.as_str())).collect();
        assert_eq!(e, vec![("m.a", "m.b", "m::S::inner")]);
    }

    #[test]
    fn drop_and_block_scope_end_regions() {
        let src = "\
impl S {
    pub fn dropped(&self) {
        let g = self.a.lock();
        drop(g);
        let h = self.b.lock();
    }
    pub fn scoped(&self) {
        {
            let g = self.a.lock();
        }
        let h = self.c.lock();
    }
}
";
        let lg = graph_of(&[("src/m.rs", src)]);
        assert!(lg.edges.is_empty(), "{:?}", lg.edges);
    }

    #[test]
    fn temporary_guards_die_with_their_statement() {
        // the Runtime::load shape: every guard is a temporary, so no
        // region overlaps another acquisition and no edges are emitted
        let src = "\
impl S {
    pub fn load(&self) {
        if let Some(e) = self.cache.lock().get(k) {
            return;
        }
        *self.compile_seconds.lock() += dt;
        self.cache.lock().insert(k, v);
    }
}
";
        let lg = graph_of(&[("src/m.rs", src)]);
        assert!(lg.edges.is_empty(), "{:?}", lg.edges);
        assert!(lg.cycles.is_empty());
    }

    #[test]
    fn lint_lock_helper_counts_as_caller_acquisition() {
        let src = "\
impl S {
    // LINT-LOCK: m.state
    fn lock_state(&self) -> Guard {
        self.state.lock()
    }
    pub fn caller(&self) {
        let st = self.lock_state();
        let q = self.rx.lock();
    }
}
";
        let lg = graph_of(&[("src/m.rs", src)]);
        assert!(
            lg.edges
                .iter()
                .any(|e| e.from == "m.state" && e.to == "m.rx" && e.via.ends_with("caller")),
            "{:?}",
            lg.edges
        );
        assert!(lg.cycles.is_empty());
    }

    #[test]
    fn json_shape() {
        let src = "\
impl S {
    pub fn outer(&self) {
        let g = self.a.lock();
        let h = self.b.lock();
    }
}
";
        let j = graph_of(&[("src/m.rs", src)]).to_json();
        assert!(j.contains("\"locks\": [\"m.a\", \"m.b\"]"), "{j}");
        assert!(j.contains("\"from\": \"m.a\", \"to\": \"m.b\""), "{j}");
        assert!(j.contains("\"cycles\": []"), "{j}");
    }
}
