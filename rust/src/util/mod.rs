//! From-scratch substrate utilities (the offline vendor set has no
//! clap/serde/rand/proptest — DESIGN.md §4 lists these as deliberate
//! substrate builds).

pub mod chk;
pub mod cli;
pub mod fft;
pub mod json;
pub mod linalg;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod sync;
pub mod threadpool;
