//! Shared blocked-GEMM kernel layer for every native hot path.
//!
//! The paper's pitch is that the O(N·S·d) recursive STLT makes
//! attention-free execution *hardware*-bound, not algorithm-bound — but
//! that only holds if the projections around the linear-time core run
//! at GEMM speed (the same observation LATTE and the linear-attention
//! line make about their wall-clock claims). This module is the one
//! place matrix kernels live: the forward engine
//! ([`crate::runtime::native_stlt`]), the hand-derived backward pass
//! ([`crate::train::backward`]) and the benches all call these exact
//! functions, so the two sides of training can never drift numerically.
//!
//! Design (dependency-free f32, no SIMD intrinsics):
//!
//! * **8-wide unrolled micro-kernels** — [`dot`] keeps eight
//!   independent accumulators and [`axpy`] updates eight lanes per
//!   step, giving the ILP (and autovectorization surface) the naive
//!   scalar triple loops with per-element `== 0.0` branches never had.
//! * **Cache blocking** — [`gemm_at`] tiles the packed operand so a
//!   panel of output rows stays in L1/L2 while the activation rows
//!   stream; [`gemm`]/[`gemm_ta`] block the shared/output dimension so
//!   the accumulator panel stays hot.
//! * **Determinism across chunking** — every `out[t, j]` of
//!   [`gemm_at`] is exactly `dot(a_t, bt_j)`, independent of `n` and of
//!   the blocking, so streaming a sequence in chunks produces bitwise
//!   the same projections as one whole-sequence call. [`gemm`] and
//!   [`gemm_ta`] accumulate their shared dimension in increasing index
//!   order regardless of block boundaries, for the same reason.
//! * **Packed panels** — weights are stored input-major (`[d, k]`) in
//!   the flat parameter vector; [`transpose`] repacks them
//!   output-major (`[k, d]`) once per bound parameter vector (see
//!   `StltPlan::bind`), so the `n = 1` decode path is `k` contiguous
//!   dot products instead of `d` strided broadcasts, and never
//!   re-packs per token.
//!
//! The tanh-GELU pair ([`gelu`], [`gelu_grad`]) lives here for the same
//! single-source reason; [`bias_gelu`] is the fused FFN epilogue.

/// sqrt(2/pi), the tanh-GELU constant — shared by the forward engine
/// and the backward pass so the approximation can never disagree.
pub const GELU_C: f32 = 0.797_884_6;

/// tanh-approximated GELU, matching `jax.nn.gelu` (approximate=True).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + 0.044_715 * x * x * x)).tanh())
}

/// d/dx of [`gelu`] (same constant, same approximation).
#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    let th = (GELU_C * (x + 0.044_715 * x * x * x)).tanh();
    0.5 * (1.0 + th) + 0.5 * x * (1.0 - th * th) * GELU_C * (1.0 + 3.0 * 0.044_715 * x * x)
}

/// Shared-dimension block size in f32 elements: tiles are sized so an
/// operand panel of `BLOCK_ELEMS` floats (32 KiB) fits L1 with room for
/// the streaming side.
const BLOCK_ELEMS: usize = 8192;

fn block_rows(row_len: usize) -> usize {
    (BLOCK_ELEMS / row_len.max(1)).clamp(8, 512)
}

/// Dot product with eight independent accumulators. The lane layout —
/// and therefore the floating-point summation order — depends only on
/// the vector length, never on the caller or any blocking, which is
/// what makes chunked and whole-sequence forwards bitwise identical.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ta, tb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        let xa: &[f32; 8] = xa.try_into().unwrap();
        let xb: &[f32; 8] = xb.try_into().unwrap();
        for l in 0..8 {
            acc[l] += xa[l] * xb[l];
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ta.iter().zip(tb) {
        tail += x * y;
    }
    (((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]))) + tail
}

/// `y += alpha * x`, 8-wide unrolled. No zero-skip branch: the kernels
/// are branchless by design (the old per-element `== 0.0` tests cost
/// more than they saved on dense activations).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let cx = x.chunks_exact(8);
    let tx = cx.remainder();
    for (ya, xa) in y.chunks_exact_mut(8).zip(cx) {
        let xa: &[f32; 8] = xa.try_into().unwrap();
        let ya: &mut [f32; 8] = ya.try_into().unwrap();
        for l in 0..8 {
            ya[l] += alpha * xa[l];
        }
    }
    let head = x.len() - tx.len();
    for (yv, xv) in y[head..].iter_mut().zip(tx) {
        *yv += alpha * xv;
    }
}

/// Repack a row-major `[rows, cols]` matrix as `[cols, rows]` — the
/// "packed panel" layout [`gemm_at`]/[`gemv`] consume, built once per
/// bound parameter vector.
pub fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(src.len(), rows * cols);
    let mut out = vec![0.0f32; rows * cols];
    for (r, row) in src.chunks_exact(cols.max(1)).enumerate() {
        for (c, &v) in row.iter().enumerate() {
            out[c * rows + r] = v;
        }
    }
    out
}

/// `out [n, k] += a [n, d] @ B` with `B` supplied **pre-transposed** as
/// `bt [k, d]` (each output column one contiguous row — the packed
/// panel layout, which the tied head's `[vocab, d]` embedding matrix
/// already has naturally).
///
/// Blocked over `bt` rows so a panel stays in cache while the `a` rows
/// stream; `out[t, j]` is exactly `dot(a_t, bt_j)` for any `n` and any
/// blocking.
pub fn gemm_at(a: &[f32], bt: &[f32], out: &mut [f32], n: usize, d: usize, k: usize) {
    debug_assert!(a.len() >= n * d && bt.len() >= k * d && out.len() >= n * k);
    if n == 1 {
        // the decode shape: skip the tiling bookkeeping entirely
        return gemv(&a[..d], bt, &mut out[..k], d, k);
    }
    let jb = block_rows(d);
    let mut j0 = 0;
    while j0 < k {
        let j1 = (j0 + jb).min(k);
        for t in 0..n {
            let ar = &a[t * d..(t + 1) * d];
            let or = &mut out[t * k + j0..t * k + j1];
            for (o, j) in or.iter_mut().zip(j0..j1) {
                *o += dot(ar, &bt[j * d..(j + 1) * d]);
            }
        }
        j0 = j1;
    }
}

/// `out [n, k] += a [n, d] @ b [d, k]` with `b` in its natural
/// input-major layout (used where no packed panel exists, e.g. the
/// `dy @ Wᵀ`-style adjoint products in the backward pass, where the
/// original weight rows are already contiguous in the needed order).
///
/// Blocked over the shared dimension so a `b` panel stays hot across
/// rows; within one output row the `i`-terms accumulate in increasing
/// order, so blocking never reorders the sum.
pub fn gemm(a: &[f32], b: &[f32], out: &mut [f32], n: usize, d: usize, k: usize) {
    debug_assert!(a.len() >= n * d && b.len() >= d * k && out.len() >= n * k);
    let ib = block_rows(k);
    let mut i0 = 0;
    while i0 < d {
        let i1 = (i0 + ib).min(d);
        for t in 0..n {
            let ar = &a[t * d..(t + 1) * d];
            let or = &mut out[t * k..(t + 1) * k];
            for i in i0..i1 {
                axpy(ar[i], &b[i * k..(i + 1) * k], or);
            }
        }
        i0 = i1;
    }
}

/// `out [d, k] += aᵀ @ b` for `a [n, d]`, `b [n, k]` — the
/// weight-gradient shape (`dW += xᵀ dy`). Blocked over output rows so
/// the accumulator panel stays in cache while the `b` rows stream; per
/// output element the `t`-terms accumulate in increasing order.
pub fn gemm_ta(a: &[f32], b: &[f32], out: &mut [f32], n: usize, d: usize, k: usize) {
    debug_assert!(a.len() >= n * d && b.len() >= n * k && out.len() >= d * k);
    let ib = block_rows(k);
    let mut i0 = 0;
    while i0 < d {
        let i1 = (i0 + ib).min(d);
        for t in 0..n {
            let ar = &a[t * d..(t + 1) * d];
            let br = &b[t * k..(t + 1) * k];
            for i in i0..i1 {
                axpy(ar[i], br, &mut out[i * k..(i + 1) * k]);
            }
        }
        i0 = i1;
    }
}

/// `out [k] += x [d] @ B` with `B` pre-transposed as `bt [k, d]`: the
/// single-token decode projection, `k` contiguous dot products over the
/// packed panel. [`gemm_at`] delegates its `n = 1` case here, so the
/// decode path takes this kernel through every projection.
pub fn gemv(x: &[f32], bt: &[f32], out: &mut [f32], d: usize, k: usize) {
    debug_assert!(x.len() >= d && bt.len() >= k * d && out.len() >= k);
    for (j, o) in out.iter_mut().enumerate().take(k) {
        *o += dot(&x[..d], &bt[j * d..(j + 1) * d]);
    }
}

/// Add `bias` to every `bias.len()`-wide row of `h` (the pre-GELU FFN
/// activations the training tape records).
pub fn add_bias(h: &mut [f32], bias: &[f32]) {
    for row in h.chunks_exact_mut(bias.len().max(1)) {
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Fused FFN epilogue: `h[t, :] = gelu(h[t, :] + bias)` in one pass.
/// Element-for-element identical to [`add_bias`] followed by a GELU
/// map, so the engine (fused) and the tape (split, to keep the
/// pre-GELU activations) stay bitwise equal.
pub fn bias_gelu(h: &mut [f32], bias: &[f32]) {
    for row in h.chunks_exact_mut(bias.len().max(1)) {
        for (v, &b) in row.iter_mut().zip(bias) {
            *v = gelu(*v + b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                "{what}[{i}]: {x} vs {y}"
            );
        }
    }

    /// Scalar triple-loop oracle: out += a @ b, b input-major [d, k].
    fn naive_gemm(a: &[f32], b: &[f32], out: &mut [f32], n: usize, d: usize, k: usize) {
        for t in 0..n {
            for i in 0..d {
                for j in 0..k {
                    out[t * k + j] += a[t * d + i] * b[i * k + j];
                }
            }
        }
    }

    // odd shapes, the n=1 decode shape, and sizes crossing block edges
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 64, 256),  // decode: one token against a packed panel
        (3, 7, 13),
        (5, 8, 8),
        (12, 17, 5),
        (2, 1024, 3),   // shared dim crosses BLOCK_ELEMS/k tiling
        (70, 65, 130),  // everything off the 8-lane boundary
        (16, 256, 600), // bt tile count > 1 at d=256 (block_rows = 32)
    ];

    #[test]
    fn gemm_matches_naive_oracle() {
        for &(n, d, k) in SHAPES {
            let a = randv(n * d, 1);
            let b = randv(d * k, 2);
            let mut want = randv(n * k, 3); // nonzero init: += semantics
            let mut got = want.clone();
            naive_gemm(&a, &b, &mut want, n, d, k);
            gemm(&a, &b, &mut got, n, d, k);
            assert_close(&got, &want, 1e-5, &format!("gemm {n}x{d}x{k}"));
        }
    }

    #[test]
    fn gemm_at_matches_naive_oracle_via_transpose() {
        for &(n, d, k) in SHAPES {
            let a = randv(n * d, 4);
            let b = randv(d * k, 5);
            let bt = transpose(&b, d, k);
            let mut want = randv(n * k, 6);
            let mut got = want.clone();
            naive_gemm(&a, &b, &mut want, n, d, k);
            gemm_at(&a, &bt, &mut got, n, d, k);
            assert_close(&got, &want, 1e-5, &format!("gemm_at {n}x{d}x{k}"));
        }
    }

    #[test]
    fn gemm_ta_matches_naive_oracle() {
        for &(n, d, k) in SHAPES {
            let a = randv(n * d, 7);
            let b = randv(n * k, 8);
            let mut want = randv(d * k, 9);
            let mut got = want.clone();
            for t in 0..n {
                for i in 0..d {
                    for j in 0..k {
                        want[i * k + j] += a[t * d + i] * b[t * k + j];
                    }
                }
            }
            gemm_ta(&a, &b, &mut got, n, d, k);
            assert_close(&got, &want, 1e-5, &format!("gemm_ta {n}x{d}x{k}"));
        }
    }

    #[test]
    fn gemv_matches_naive_oracle() {
        // gemm_at(n = 1) delegates here, so this pins the decode shape
        // against the scalar oracle directly
        for &(_, d, k) in SHAPES {
            let x = randv(d, 10);
            let b = randv(d * k, 11);
            let bt = transpose(&b, d, k);
            let mut want = randv(k, 20);
            let mut got = want.clone();
            naive_gemm(&x, &b, &mut want, 1, d, k);
            gemv(&x, &bt, &mut got, d, k);
            assert_close(&got, &want, 1e-5, &format!("gemv {d}x{k}"));
        }
    }

    #[test]
    fn gemm_at_is_chunk_invariant_bitwise() {
        // the streaming guarantee: projecting rows in chunks must equal
        // one whole-sequence call bit-for-bit
        let (n, d, k) = (23, 40, 50);
        let a = randv(n * d, 12);
        let bt = randv(k * d, 13);
        let mut whole = vec![0.0f32; n * k];
        gemm_at(&a, &bt, &mut whole, n, d, k);
        let mut pieces = vec![0.0f32; n * k];
        let mut t0 = 0;
        for step in [1usize, 7, 2, 13] {
            let t1 = (t0 + step).min(n);
            gemm_at(&a[t0 * d..t1 * d], &bt, &mut pieces[t0 * k..t1 * k], t1 - t0, d, k);
            t0 = t1;
        }
        assert_eq!(whole, pieces, "chunked gemm_at must be bitwise whole-call");
    }

    #[test]
    fn transpose_round_trips() {
        let (r, c) = (9, 14);
        let src = randv(r * c, 14);
        let t = transpose(&src, r, c);
        assert_eq!(transpose(&t, c, r), src);
        assert_eq!(t[3 * r + 2], src[2 * c + 3]);
    }

    #[test]
    fn bias_gelu_matches_split_form() {
        let (n, k) = (6, 21);
        let bias = randv(k, 15);
        let mut fused = randv(n * k, 16);
        let mut split = fused.clone();
        bias_gelu(&mut fused, &bias);
        add_bias(&mut split, &bias);
        for v in split.iter_mut() {
            *v = gelu(*v);
        }
        assert_eq!(fused, split, "fused epilogue must be bitwise the split form");
    }

    #[test]
    fn dot_and_axpy_handle_tails() {
        for len in [0usize, 1, 7, 8, 9, 16, 31] {
            let a = randv(len, 17);
            let b = randv(len, 18);
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - want).abs() < 1e-5 * (1.0 + want.abs()), "dot len {len}");
            let mut y = randv(len, 19);
            let y0 = y.clone();
            axpy(0.5, &a, &mut y);
            for i in 0..len {
                assert!((y[i] - (y0[i] + 0.5 * a[i])).abs() < 1e-6, "axpy len {len} at {i}");
            }
        }
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for x in [-3.0f32, -0.7, 0.0, 0.3, 2.5] {
            let eps = 1e-3f32;
            let fd = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!((gelu_grad(x) - fd).abs() < 1e-3, "gelu'({x}): {} vs {fd}", gelu_grad(x));
        }
    }
}
