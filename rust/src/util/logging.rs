//! Tiny leveled logger (no `log`/`env_logger` wiring needed): timestamps
//! relative to process start, level filter via STLT_LOG env (error..trace).

use std::time::Instant;

use crate::util::sync::atomic::{AtomicU8, Ordering};
use crate::util::sync::{Once, OnceLock};

static LEVEL: AtomicU8 = AtomicU8::new(2); // info
static INIT: Once = Once::new();
static START: OnceLock<Instant> = OnceLock::new();

#[derive(Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

pub fn init() {
    INIT.call_once(|| {
        let _ = START.set(Instant::now());
        if let Ok(v) = std::env::var("STLT_LOG") {
            let l = match v.to_lowercase().as_str() {
                "error" => 0,
                "warn" => 1,
                "info" => 2,
                "debug" => 3,
                "trace" => 4,
                _ => 2,
            };
            // ORDERING: Relaxed — LEVEL is an independent filter knob;
            // a stale read only mis-filters a log line, never breaks
            // an invariant, and `Once` already orders init itself.
            LEVEL.store(l, Ordering::Relaxed);
        }
    });
}

pub fn set_level(l: Level) {
    init();
    // ORDERING: Relaxed — see init(): no other memory is published via
    // this flag, late observers just filter at the old level briefly.
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    init();
    // ORDERING: Relaxed — pure filter read; no data is gated on it.
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// The single process timebase: set once by the first `init()` (or the
/// first caller of this function). Log timestamps and [`crate::obs`]
/// span timestamps are both measured against it, so a trace viewed in
/// Perfetto lines up with the stderr log.
pub fn timebase() -> Instant {
    *START.get_or_init(Instant::now)
}

pub fn elapsed_s() -> f64 {
    init();
    timebase().elapsed().as_secs_f64()
}

pub fn log(l: Level, tag: &str, msg: &str) {
    if enabled(l) {
        let name = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{:9.3}s {} {}] {}", elapsed_s(), name, tag, msg);
    }
}

#[macro_export]
macro_rules! info {
    ($tag:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $tag, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnlog {
    ($tag:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $tag, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debuglog {
    ($tag:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $tag, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn elapsed_monotonic() {
        let a = elapsed_s();
        let b = elapsed_s();
        assert!(b >= a);
    }
}
