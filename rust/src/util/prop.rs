//! Mini property-testing framework (no proptest offline).
//!
//! `check(name, cases, |g| { ... })` runs the closure `cases` times with
//! a fresh `Gen` per case; on failure it reports the case seed so the
//! exact input is reproducible with `replay(seed, ...)`.

use super::rng::Rng;

pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range(lo, hi + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| lo + self.rng.f32() * (hi - lo)).collect()
    }

    pub fn vec_i32(&mut self, len: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..len).map(|_| self.rng.range(lo as i64, hi as i64) as i32).collect()
    }

    pub fn tokens(&mut self, len: usize, vocab: i32) -> Vec<i32> {
        self.vec_i32(len, 0, vocab)
    }
}

/// Run `f` for `cases` random cases. Panics with the failing seed on error.
pub fn check<F: FnMut(&mut Gen) -> Result<(), String>>(name: &str, cases: u64, mut f: F) {
    let base = 0xC0FFEE ^ name.bytes().fold(0u64, |a, b| a.wrapping_mul(31).wrapping_add(b as u64));
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen { rng: Rng::new(seed), seed };
        if let Err(msg) = f(&mut g) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay<F: FnMut(&mut Gen) -> Result<(), String>>(seed: u64, mut f: F) {
    let mut g = Gen { rng: Rng::new(seed), seed };
    if let Err(msg) = f(&mut g) {
        panic!("replay(seed {seed:#x}) failed: {msg}");
    }
}

/// Assert helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 50, |g| {
            let a = g.i64_in(-1000, 1000);
            let b = g.i64_in(-1000, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed on case")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 3, |_g| Err("nope".into()));
    }

    #[test]
    fn deterministic_cases() {
        let mut seen = Vec::new();
        check("collect", 5, |g| {
            seen.push(g.usize_in(0, 1_000_000));
            Ok(())
        });
        let mut seen2 = Vec::new();
        check("collect", 5, |g| {
            seen2.push(g.usize_in(0, 1_000_000));
            Ok(())
        });
        assert_eq!(seen, seen2);
    }
}
