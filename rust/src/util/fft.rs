//! From-scratch iterative radix-2 complex FFT.
//!
//! The paper (§3.4, abstract) advertises an "efficient FFT-based
//! computation of the relevance matrix" in O(N S log S). This substrate
//! provides the FFT; `exp_scaling --error`-style analyses and the
//! substrate bench use it to cross-check the direct relevance
//! computation against its spectral form (Parseval: the S-point
//! spectrum of L_{n,·} preserves inner products, so
//! R_{n,m} = Re<L_n, L_m> can equivalently be computed on FFT(L_n)).

/// In-place iterative Cooley–Tukey FFT over (re, im) slices.
/// `len` must be a power of two. `inverse` applies 1/len scaling.
pub fn fft_inplace(re: &mut [f32], im: &mut [f32], inverse: bool) {
    let n = re.len();
    assert_eq!(n, im.len());
    assert!(n.is_power_of_two(), "fft length {n} not a power of two");
    if n <= 1 {
        return;
    }
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let sign = if inverse { 1.0f64 } else { -1.0f64 };
    let mut len = 2usize;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (ar, ai) = (re[i + k] as f64, im[i + k] as f64);
                let (br, bi) = (re[i + k + len / 2] as f64, im[i + k + len / 2] as f64);
                let (tr, ti) = (br * cr - bi * ci, br * ci + bi * cr);
                re[i + k] = (ar + tr) as f32;
                im[i + k] = (ai + ti) as f32;
                re[i + k + len / 2] = (ar - tr) as f32;
                im[i + k + len / 2] = (ai - ti) as f32;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
            i += len;
        }
        len <<= 1;
    }
    if inverse {
        let inv = 1.0 / n as f32;
        for x in re.iter_mut() {
            *x *= inv;
        }
        for x in im.iter_mut() {
            *x *= inv;
        }
    }
}

/// Forward FFT of a complex vector, padding to the next power of two.
pub fn fft(re: &[f32], im: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let n = re.len().next_power_of_two();
    let mut r = re.to_vec();
    let mut i = im.to_vec();
    r.resize(n, 0.0);
    i.resize(n, 0.0);
    fft_inplace(&mut r, &mut i, false);
    (r, i)
}

/// Relevance between two node vectors computed directly:
/// Re<a, b> = sum_k (a_re b_re + a_im b_im).
pub fn relevance_direct(a_re: &[f32], a_im: &[f32], b_re: &[f32], b_im: &[f32]) -> f32 {
    a_re.iter()
        .zip(b_re)
        .map(|(x, y)| x * y)
        .chain(a_im.iter().zip(b_im).map(|(x, y)| x * y))
        .sum()
}

/// Relevance via the S-point spectra (§3.4): Parseval gives
/// Re<a, b> = Re<FFT(a), FFT(b)> / S_fft.
pub fn relevance_spectral(a_re: &[f32], a_im: &[f32], b_re: &[f32], b_im: &[f32]) -> f32 {
    let (ar, ai) = fft(a_re, a_im);
    let (br, bi) = fft(b_re, b_im);
    let n = ar.len() as f32;
    relevance_direct(&ar, &ai, &br, &bi) / n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fft_of_impulse_is_flat() {
        let mut re = vec![0.0f32; 8];
        let mut im = vec![0.0f32; 8];
        re[0] = 1.0;
        fft_inplace(&mut re, &mut im, false);
        for k in 0..8 {
            assert!((re[k] - 1.0).abs() < 1e-5 && im[k].abs() < 1e-5);
        }
    }

    #[test]
    fn fft_roundtrip() {
        let mut rng = Rng::new(3);
        let re0: Vec<f32> = (0..64).map(|_| rng.f32() - 0.5).collect();
        let im0: Vec<f32> = (0..64).map(|_| rng.f32() - 0.5).collect();
        let mut re = re0.clone();
        let mut im = im0.clone();
        fft_inplace(&mut re, &mut im, false);
        fft_inplace(&mut re, &mut im, true);
        for k in 0..64 {
            assert!((re[k] - re0[k]).abs() < 1e-4);
            assert!((im[k] - im0[k]).abs() < 1e-4);
        }
    }

    #[test]
    fn fft_matches_dft_definition() {
        let mut rng = Rng::new(7);
        let re0: Vec<f32> = (0..16).map(|_| rng.f32() - 0.5).collect();
        let im0: Vec<f32> = (0..16).map(|_| rng.f32() - 0.5).collect();
        let (fr, fi) = fft(&re0, &im0);
        for k in 0..16 {
            let (mut sr, mut si) = (0.0f64, 0.0f64);
            for t in 0..16 {
                let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / 16.0;
                let (c, s) = (ang.cos(), ang.sin());
                sr += re0[t] as f64 * c - im0[t] as f64 * s;
                si += re0[t] as f64 * s + im0[t] as f64 * c;
            }
            assert!((fr[k] as f64 - sr).abs() < 1e-3, "k={k}");
            assert!((fi[k] as f64 - si).abs() < 1e-3, "k={k}");
        }
    }

    #[test]
    fn parseval_relevance_equivalence() {
        // the §3.4 claim: relevance can be computed in the spectral domain
        let mut rng = Rng::new(11);
        for _ in 0..20 {
            let s = 32;
            let a_re: Vec<f32> = (0..s).map(|_| rng.f32() - 0.5).collect();
            let a_im: Vec<f32> = (0..s).map(|_| rng.f32() - 0.5).collect();
            let b_re: Vec<f32> = (0..s).map(|_| rng.f32() - 0.5).collect();
            let b_im: Vec<f32> = (0..s).map(|_| rng.f32() - 0.5).collect();
            let direct = relevance_direct(&a_re, &a_im, &b_re, &b_im);
            let spectral = relevance_spectral(&a_re, &a_im, &b_re, &b_im);
            assert!(
                (direct - spectral).abs() < 1e-3 * (1.0 + direct.abs()),
                "{direct} vs {spectral}"
            );
        }
    }

    #[test]
    fn fft_wrapper_zero_pads_non_power_of_two() {
        // the fft() wrapper pads to the next power of two; its output
        // must equal the DFT of the explicitly zero-padded signal
        let mut rng = Rng::new(19);
        for len in [1usize, 3, 5, 12, 17] {
            let re0: Vec<f32> = (0..len).map(|_| rng.f32() - 0.5).collect();
            let im0: Vec<f32> = (0..len).map(|_| rng.f32() - 0.5).collect();
            let (fr, fi) = fft(&re0, &im0);
            let n = len.next_power_of_two();
            assert_eq!(fr.len(), n, "padded length for input {len}");
            assert_eq!(fi.len(), n);
            for k in 0..n {
                let (mut sr, mut si) = (0.0f64, 0.0f64);
                for t in 0..len {
                    // terms t >= len are zero padding
                    let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                    let (c, s) = (ang.cos(), ang.sin());
                    sr += re0[t] as f64 * c - im0[t] as f64 * s;
                    si += re0[t] as f64 * s + im0[t] as f64 * c;
                }
                assert!((fr[k] as f64 - sr).abs() < 1e-3, "len={len} k={k}");
                assert!((fi[k] as f64 - si).abs() < 1e-3, "len={len} k={k}");
            }
        }
    }

    #[test]
    fn spectral_relevance_handles_padded_lengths() {
        // relevance_spectral goes through the zero-padding wrapper for
        // non-power-of-two node counts; Parseval must still hold
        let mut rng = Rng::new(23);
        for s in [3usize, 7, 12] {
            let a_re: Vec<f32> = (0..s).map(|_| rng.f32() - 0.5).collect();
            let a_im: Vec<f32> = (0..s).map(|_| rng.f32() - 0.5).collect();
            let b_re: Vec<f32> = (0..s).map(|_| rng.f32() - 0.5).collect();
            let b_im: Vec<f32> = (0..s).map(|_| rng.f32() - 0.5).collect();
            let direct = relevance_direct(&a_re, &a_im, &b_re, &b_im);
            let spectral = relevance_spectral(&a_re, &a_im, &b_re, &b_im);
            assert!(
                (direct - spectral).abs() < 1e-3 * (1.0 + direct.abs()),
                "S={s}: {direct} vs {spectral}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_power_of_two_panics() {
        let mut re = vec![0.0f32; 12];
        let mut im = vec![0.0f32; 12];
        fft_inplace(&mut re, &mut im, false);
    }
}
