//! From-scratch CLI argument parser (no clap offline).
//!
//! Grammar: `prog <subcommand> [--key value | --key=value | --flag] [pos...]`

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    /// last occurrence wins (lookup via `get`/`get_or`)
    pub options: BTreeMap<String, String>,
    /// every `--key value` occurrence in argv order (lookup via `get_all`
    /// for repeatable options like `--set section.key=value`)
    pub occurrences: Vec<(String, String)>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding program name). `flag_names` lists options
    /// that take no value.
    pub fn parse(argv: &[String], flag_names: &[&str]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.occurrences.push((k.to_string(), v.to_string()));
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        return Err(format!("option --{rest} expects a value"));
                    }
                    let val = it.next().unwrap().clone();
                    out.occurrences.push((rest.to_string(), val.clone()));
                    out.options.insert(rest.to_string(), val);
                } else {
                    return Err(format!("option --{rest} expects a value"));
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env(flag_names: &[&str]) -> Result<Args, String> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, flag_names)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Every value given for a repeatable option, in argv order
    /// (e.g. `--set a.x=1 --set a.y=2` -> ["a.x=1", "a.y=2"]).
    pub fn get_all(&self, key: &str) -> Vec<String> {
        self.occurrences
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.clone())
            .collect()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad integer '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad integer '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad float '{v}'")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn subcommand_and_options() {
        let a = Args::parse(&v(&["train", "--steps", "100", "--lr=0.001"]), &[]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.001);
    }

    #[test]
    fn flags_and_positional() {
        let a = Args::parse(&v(&["eval", "ckpt.bin", "--verbose"]), &["verbose"]).unwrap();
        assert_eq!(a.positional, vec!["ckpt.bin"]);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&v(&["x", "--steps"]), &[]).is_err());
        assert!(Args::parse(&v(&["x", "--steps", "--other", "1"]), &[]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&v(&["run"]), &[]).unwrap();
        assert_eq!(a.get_or("name", "d"), "d");
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
    }

    #[test]
    fn repeated_options_accumulate() {
        let a = Args::parse(
            &v(&["train", "--set", "train.steps=5", "--set=data.seed=9", "--steps", "3"]),
            &[],
        )
        .unwrap();
        assert_eq!(a.get_all("set"), vec!["train.steps=5", "data.seed=9"]);
        // last-wins map still sees the final occurrence
        assert_eq!(a.get_or("set", ""), "data.seed=9");
        assert!(a.get_all("nope").is_empty());
    }

    #[test]
    fn bad_number_reports_key() {
        let a = Args::parse(&v(&["x", "--n", "abc"]), &[]).unwrap();
        let e = a.get_usize("n", 0).unwrap_err();
        assert!(e.contains("--n"));
    }
}
