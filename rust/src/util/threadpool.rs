//! Minimal fixed-size thread pool (no tokio/rayon offline).
//!
//! Jobs are `FnOnce + Send` closures; `join()` blocks until the queue is
//! drained. The coordinator's server uses this for its worker threads;
//! note the PJRT executor itself is driven from a single model thread
//! (the CPU client is not profitably shared across threads on 1 core).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(
                thread::Builder::new()
                    .name(format!("stlt-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cv) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                cv.notify_all();
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx: Some(tx), workers, pending }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }

    /// Block until every submitted job has finished.
    pub fn join(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(i)` for i in 0..n across the pool, collecting results in order.
pub fn parallel_map<T: Send + 'static, F>(pool: &ThreadPool, n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let results: Arc<Mutex<Vec<Option<T>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    let done = Arc::new(AtomicUsize::new(0));
    for i in 0..n {
        let f = Arc::clone(&f);
        let results = Arc::clone(&results);
        let done = Arc::clone(&done);
        pool.execute(move || {
            let r = f(i);
            results.lock().unwrap()[i] = Some(r);
            done.fetch_add(1, Ordering::SeqCst);
        });
    }
    pool.join();
    Arc::try_unwrap(results)
        .unwrap_or_else(|_| panic!("pool leak"))
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("job missing"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_order() {
        let pool = ThreadPool::new(3);
        let out = parallel_map(&pool, 20, |i| i * i);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn join_idempotent() {
        let pool = ThreadPool::new(2);
        pool.join();
        pool.execute(|| {});
        pool.join();
        pool.join();
    }
}
