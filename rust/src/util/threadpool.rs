//! Minimal fixed-size thread pool (no tokio/rayon offline).
//!
//! Jobs are `FnOnce + Send` closures; `join()` blocks until the queue
//! is drained. Panic-safe: a panicking job decrements the pending
//! counter through a drop guard and its unwind is caught on the worker,
//! so the worker thread survives, the mutex is never poisoned, and the
//! panic message is surfaced by the next `join`/[`ThreadPool::try_join`]
//! instead of deadlocking the coordinator (the old implementation left
//! `pending` stuck forever and poisoned the lock).
//!
//! [`global`] is the process-wide pool the native backend and the
//! row-parallel kernels share; [`in_worker`] marks pool worker threads
//! so nested fan-outs ([`parallel_map`] from inside a job) run inline
//! instead of parking a worker in `join()` on its own queue — the
//! classic self-join deadlock. [`scatter_rows`] is the borrowing
//! (scoped) row-parallel primitive the STLT engine uses for the tied
//! head and FFN.

use std::any::Any;
use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// True on any [`ThreadPool`] worker thread (of any pool). Nested
/// parallel primitives consult this to run inline rather than enqueue
/// work a blocked worker would wait on.
pub fn in_worker() -> bool {
    IN_WORKER.with(|w| w.get())
}

/// The process-wide shared pool, lazily sized to the available
/// parallelism. The native backend and the row-parallel eval/train
/// paths all draw from this one pool so the machine is never
/// oversubscribed by stacked per-component pools.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    })
}

#[derive(Default)]
struct PoolState {
    pending: usize,
    panics: Vec<String>,
}

struct Shared {
    state: Mutex<PoolState>,
    cv: Condvar,
}

impl Shared {
    /// The state critical sections are panic-free, but never propagate
    /// a poison either way — a poisoned pool must still drain.
    fn lock_state(&self) -> MutexGuard<'_, PoolState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Decrements `pending` and wakes joiners on drop, so the accounting
/// survives a panicking job (satellite fix: the old pool decremented
/// only on the success path).
struct PendingGuard<'a> {
    shared: &'a Shared,
}

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        self.shared.lock_state().pending -= 1;
        self.shared.cv.notify_all();
    }
}

fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState::default()),
            cv: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let shared = Arc::clone(&shared);
            let worker = thread::Builder::new()
                .name(format!("stlt-worker-{i}"))
                .spawn(move || {
                    IN_WORKER.with(|w| w.set(true));
                    loop {
                        let job = { rx.lock().unwrap_or_else(|e| e.into_inner()).recv() };
                        match job {
                            Ok(job) => {
                                let _guard = PendingGuard { shared: &shared };
                                if let Err(p) = catch_unwind(AssertUnwindSafe(job)) {
                                    shared.lock_state().panics.push(panic_message(p.as_ref()));
                                }
                            }
                            Err(_) => break,
                        }
                    }
                })
                .expect("spawn worker");
            workers.push(worker);
        }
        ThreadPool { tx: Some(tx), workers, shared }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.lock_state().pending += 1;
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }

    /// Block until every submitted job has finished. Panics (on this,
    /// the coordinating thread) with the collected messages if any job
    /// panicked — see [`ThreadPool::try_join`] for the non-panicking
    /// form.
    pub fn join(&self) {
        if let Err(e) = self.try_join() {
            panic!("{e}");
        }
    }

    /// Block until the queue drains, then report (and clear) any job
    /// panics that occurred since the last join. The queue counter is
    /// pool-global, so concurrent submitters wait on each other's jobs
    /// (unchanged semantics) and may observe each other's panics.
    pub fn try_join(&self) -> Result<(), String> {
        let mut st = self.shared.lock_state();
        while st.pending > 0 {
            st = self.shared.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if st.panics.is_empty() {
            Ok(())
        } else {
            let panics = std::mem::take(&mut st.panics);
            Err(format!("{} pool job(s) panicked: {}", panics.len(), panics.join("; ")))
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(i)` for i in 0..n across the pool, collecting results in
/// order.
///
/// Runs inline on the calling thread when `n <= 1` or when called from
/// inside a pool worker — a nested fan-out would park the worker in
/// `join()` behind its own unfinished slot. If a job panics, the panic
/// is re-raised here once the queue has drained (instead of the old
/// behaviour: a permanent deadlock on the never-decremented counter).
pub fn parallel_map<T: Send + 'static, F>(pool: &ThreadPool, n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Send + Sync + 'static,
{
    if n <= 1 || in_worker() {
        return (0..n).map(f).collect();
    }
    let f = Arc::new(f);
    let results: Arc<Mutex<Vec<Option<T>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    for i in 0..n {
        let f = Arc::clone(&f);
        let results = Arc::clone(&results);
        pool.execute(move || {
            let r = f(i);
            results.lock().unwrap()[i] = Some(r);
        });
    }
    pool.join();
    Arc::try_unwrap(results)
        .unwrap_or_else(|_| panic!("pool leak"))
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("job missing"))
        .collect()
}

/// Row-parallel scatter over borrowed data: split `out` (`n` rows of
/// `row_len` f32s) into one contiguous chunk per available core and run
/// `f(t0, t1, chunk)` concurrently on scoped threads, with the last
/// chunk executing on the calling thread.
///
/// This is the engine-side primitive for the tied logits head and the
/// FFN (rows are independent there), kept separate from the queue pool
/// because those call sites *borrow* activations — scoped threads give
/// them parallelism without `Arc`-ing every intermediate. Runs inline
/// when `n < min_rows`, when only one core exists, or on a pool worker
/// (the batch level already owns the cores then), so nesting is always
/// deadlock- and oversubscription-free. Each out element is written by
/// exactly one chunk; parallel and inline execution agree bitwise as
/// long as `f`'s per-row output does not depend on (t0, t1) — true of
/// every kernel call site (each row is an independent set of dots).
pub fn scatter_rows<F>(n: usize, row_len: usize, out: &mut [f32], min_rows: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Send + Sync,
{
    assert!(out.len() >= n * row_len, "scatter_rows: out too small");
    let threads = thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    if n < min_rows.max(2) || threads < 2 || in_worker() {
        f(0, n, &mut out[..n * row_len]);
        return;
    }
    let nch = threads.min(n);
    let per = n.div_ceil(nch);
    thread::scope(|s| {
        let f = &f;
        let mut rest = &mut out[..n * row_len];
        let mut t0 = 0usize;
        while t0 < n {
            let t1 = (t0 + per).min(n);
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut((t1 - t0) * row_len);
            rest = tail;
            if t1 < n {
                s.spawn(move || f(t0, t1, chunk));
            } else {
                f(t0, t1, chunk); // final chunk on the calling thread
            }
            t0 = t1;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_order() {
        let pool = ThreadPool::new(3);
        let out = parallel_map(&pool, 20, |i| i * i);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn join_idempotent() {
        let pool = ThreadPool::new(2);
        pool.join();
        pool.execute(|| {});
        pool.join();
        pool.join();
    }

    #[test]
    fn panicking_job_is_surfaced_not_deadlocked() {
        // the satellite seam: before the drop-guard fix this join hung
        // forever (pending never decremented) or poisoned the mutex
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for i in 0..8 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                if i == 3 {
                    panic!("job {i} exploded");
                }
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        let err = pool.try_join().expect_err("panic must surface");
        assert!(err.contains("job 3 exploded"), "message lost: {err}");
        assert_eq!(counter.load(Ordering::SeqCst), 7, "other jobs must complete");

        // the pool (and its workers) must remain fully usable afterwards
        let c = Arc::clone(&counter);
        pool.execute(move || {
            c.fetch_add(10, Ordering::SeqCst);
        });
        pool.try_join().expect("panic report must clear the error state");
        assert_eq!(counter.load(Ordering::SeqCst), 17);
    }

    #[test]
    fn parallel_map_reraises_job_panic_on_caller() {
        let pool = ThreadPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            parallel_map(&pool, 6, |i| {
                if i == 2 {
                    panic!("row 2 bad");
                }
                i
            })
        }));
        let msg = panic_message(caught.expect_err("must re-raise").as_ref());
        assert!(msg.contains("row 2 bad"), "message lost: {msg}");
        // and again: the pool survives
        assert_eq!(parallel_map(&pool, 4, |i| i + 1), vec![1, 2, 3, 4]);
    }

    #[test]
    fn nested_parallel_map_runs_inline_without_deadlock() {
        let pool = ThreadPool::new(2);
        // 4 outer jobs on 2 workers, each fanning out again: the nested
        // calls must run inline (in_worker) or this join never returns
        let out = parallel_map(&pool, 4, |i| {
            assert!(in_worker());
            parallel_map(global(), 3, move |j| i * 10 + j)
        });
        assert_eq!(out[2], vec![20, 21, 22]);
    }

    #[test]
    fn scatter_rows_covers_every_chunk_exactly_once() {
        for n in [0usize, 1, 2, 15, 16, 33] {
            let row_len = 3;
            let mut out = vec![0.0f32; n * row_len];
            scatter_rows(n, row_len, &mut out, 16, |t0, t1, chunk| {
                assert_eq!(chunk.len(), (t1 - t0) * row_len);
                for (r, row) in chunk.chunks_exact_mut(row_len).enumerate() {
                    for v in row.iter_mut() {
                        *v += (t0 + r) as f32; // += catches double-writes
                    }
                }
            });
            for t in 0..n {
                for j in 0..row_len {
                    assert_eq!(out[t * row_len + j], t as f32, "row {t} col {j} (n={n})");
                }
            }
        }
    }
}
