//! Minimal fixed-size thread pool (no tokio/rayon offline).
//!
//! Jobs are `FnOnce + Send` closures; `join()` blocks until the queue
//! is drained. Panic-safe: a panicking job decrements the pending
//! counter through a drop guard and its unwind is caught on the worker,
//! so the worker thread survives, the mutex is never poisoned, and the
//! panic message is surfaced by the next `join`/[`ThreadPool::try_join`]
//! instead of deadlocking the coordinator (the old implementation left
//! `pending` stuck forever and poisoned the lock).
//!
//! [`global`] is the process-wide pool the native backend and the
//! row-parallel kernels share; [`in_worker`] marks pool worker threads
//! so nested fan-outs ([`parallel_map`] from inside a job) run inline
//! instead of parking a worker in `join()` on its own queue — the
//! classic self-join deadlock. [`scatter_rows`] is the borrowing
//! row-parallel primitive the STLT engine uses for the tied head and
//! FFN: it runs its chunks on the *persistent* global workers behind a
//! per-call completion latch (not per-call scoped spawns — the old
//! per-projection thread spawns were measurable on the non-batched
//! streaming/decode path on many-core boxes).
//!
//! [`configured_threads`] is the single source of truth for the worker
//! count — `STLT_THREADS` when set, else the available parallelism —
//! read by both the pool constructor and the scatter chunking, so row
//! fan-out always matches the actual worker count.

use std::any::Any;
use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::thread;

use crate::util::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// True on any [`ThreadPool`] worker thread (of any pool). Nested
/// parallel primitives consult this to run inline rather than enqueue
/// work a blocked worker would wait on.
pub fn in_worker() -> bool {
    IN_WORKER.with(|w| w.get())
}

/// Parse a worker-count override; `None`/empty/garbage/0 falls back.
/// Split out of [`configured_threads`] so the parsing is unit-testable
/// without racing on the process environment.
fn threads_from(over: Option<&str>, fallback: usize) -> usize {
    over.and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(fallback)
        .max(1)
}

/// The worker-thread count every parallel primitive derives from —
/// the single source of truth (satellite fix: the pool used to size
/// itself while `scatter_rows` separately re-read the machine
/// parallelism per call, so row fan-out could mismatch the actual
/// worker count). `STLT_THREADS` overrides the detected parallelism;
/// read once per process.
pub fn configured_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        let fallback = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        threads_from(std::env::var("STLT_THREADS").ok().as_deref(), fallback)
    })
}

/// The process-wide shared pool, lazily sized to
/// [`configured_threads`]. The native backend and the row-parallel
/// eval/train paths all draw from this one pool so the machine is
/// never oversubscribed by stacked per-component pools.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(|| ThreadPool::new(configured_threads()))
}

#[derive(Default)]
struct PoolState {
    pending: usize,
    panics: Vec<String>,
}

struct Shared {
    state: Mutex<PoolState>,
    cv: Condvar,
}

impl Shared {
    /// The state critical sections are panic-free, but never propagate
    /// a poison either way — a poisoned pool must still drain.
    fn lock_state(&self) -> MutexGuard<'_, PoolState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Decrements `pending` and wakes joiners on drop, so the accounting
/// survives a panicking job (satellite fix: the old pool decremented
/// only on the success path).
struct PendingGuard<'a> {
    shared: &'a Shared,
}

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        self.shared.lock_state().pending -= 1;
        self.shared.cv.notify_all();
    }
}

fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState::default()),
            cv: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let shared = Arc::clone(&shared);
            let worker = thread::Builder::new()
                .name(format!("stlt-worker-{i}"))
                .spawn(move || {
                    IN_WORKER.with(|w| w.set(true));
                    loop {
                        let job = { rx.lock().unwrap_or_else(|e| e.into_inner()).recv() };
                        match job {
                            Ok(job) => {
                                let _guard = PendingGuard { shared: &shared };
                                if let Err(p) = catch_unwind(AssertUnwindSafe(job)) {
                                    shared.lock_state().panics.push(panic_message(p.as_ref()));
                                }
                            }
                            Err(_) => break,
                        }
                    }
                })
                .expect("spawn worker");
            workers.push(worker);
        }
        ThreadPool { tx: Some(tx), workers, shared }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Jobs currently queued or running. This is the saturation signal
    /// behind the idle-aware inline fallback: when `pending() >=
    /// threads()` every worker is already busy, so a latency-critical
    /// fan-out (a serving decode scatter) would queue FIFO behind
    /// whatever long batch jobs are in flight instead of running now.
    pub fn pending(&self) -> usize {
        self.shared.lock_state().pending
    }

    /// True when every worker is (or is about to be) occupied — new
    /// jobs would wait in the FIFO queue rather than start immediately.
    pub fn saturated(&self) -> bool {
        self.pending() >= self.threads()
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.execute_boxed(Box::new(f));
    }

    fn execute_boxed(&self, job: Job) {
        self.shared.lock_state().pending += 1;
        self.tx.as_ref().unwrap().send(job).expect("pool closed");
    }

    /// Block until every submitted job has finished. Panics (on this,
    /// the coordinating thread) with the collected messages if any job
    /// panicked — see [`ThreadPool::try_join`] for the non-panicking
    /// form.
    pub fn join(&self) {
        if let Err(e) = self.try_join() {
            // PANIC-OK: deliberate propagation — a worker already
            // panicked; rethrowing on the coordinating thread is this
            // method's documented contract (try_join is the fallible form)
            panic!("{e}");
        }
    }

    /// Block until the queue drains, then report (and clear) any job
    /// panics that occurred since the last join. The queue counter is
    /// pool-global, so concurrent submitters wait on each other's jobs
    /// (unchanged semantics) and may observe each other's panics.
    pub fn try_join(&self) -> Result<(), String> {
        let mut st = self.shared.lock_state();
        while st.pending > 0 {
            st = self.shared.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if st.panics.is_empty() {
            Ok(())
        } else {
            let panics = std::mem::take(&mut st.panics);
            Err(format!("{} pool job(s) panicked: {}", panics.len(), panics.join("; ")))
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(i)` for i in 0..n across the pool, collecting results in
/// order.
///
/// Runs inline on the calling thread when `n <= 1` or when called from
/// inside a pool worker — a nested fan-out would park the worker in
/// `join()` behind its own unfinished slot. If a job panics, the panic
/// is re-raised here once the queue has drained (instead of the old
/// behaviour: a permanent deadlock on the never-decremented counter).
pub fn parallel_map<T: Send + 'static, F>(pool: &ThreadPool, n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Send + Sync + 'static,
{
    // No idle-aware fallback here: parallel_map carries long batch jobs
    // (training/eval rows), where serializing a whole batch onto the
    // caller because the pool was *momentarily* saturated by a
    // one-token decode wave would cost far more than briefly queueing.
    // Latency-critical callers opt in explicitly ([`scatter_rows`] and
    // the native decode_batch wave check [`ThreadPool::saturated`]).
    if n <= 1 || in_worker() {
        return (0..n).map(f).collect();
    }
    let f = Arc::new(f);
    let results: Arc<Mutex<Vec<Option<T>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    for i in 0..n {
        let f = Arc::clone(&f);
        let results = Arc::clone(&results);
        pool.execute(move || {
            let r = f(i);
            results.lock().unwrap()[i] = Some(r);
        });
    }
    pool.join();
    Arc::try_unwrap(results)
        // PANIC-OK: join() drained every job, so this Arc is the last
        // reference; a leak here means the pool broke its own contract
        .unwrap_or_else(|_| panic!("pool leak"))
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("job missing"))
        .collect()
}

/// Completion latch for one [`scatter_rows`] call: counts *completed*
/// jobs up (never the pool-global queue, which other submitters share)
/// and collects their panic messages for the caller to re-raise. It
/// counts up rather than down so the caller can wait for exactly the
/// number of jobs that were *successfully* enqueued — a job that was
/// never sent can neither be waited for (deadlock) nor underflow the
/// counter by completing before registration.
struct Latch {
    state: Mutex<(usize, Vec<String>)>,
    cv: Condvar,
}

impl Latch {
    fn new() -> Latch {
        Latch { state: Mutex::new((0, Vec::new())), cv: Condvar::new() }
    }

    /// Block until `target` jobs have finished; returns the collected
    /// panic messages.
    fn wait(&self, target: usize) -> Vec<String> {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        while st.0 < target {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        std::mem::take(&mut st.1)
    }

    fn done(&self, panic_msg: Option<String>) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.0 += 1;
        if let Some(m) = panic_msg {
            st.1.push(m);
        }
        self.cv.notify_all();
    }
}

/// Waits on the latch if [`scatter_rows`] unwinds for any reason —
/// enqueue failure mid-loop or a panic in the caller's own inline
/// chunk — so workers can never outlive the borrows they hold. Armed
/// *before* the first enqueue; `enqueued` tracks how many jobs were
/// actually sent at the moment of the unwind.
struct LatchWait<'a> {
    latch: &'a Latch,
    enqueued: &'a Cell<usize>,
}

impl Drop for LatchWait<'_> {
    fn drop(&mut self) {
        self.latch.wait(self.enqueued.get());
    }
}

/// Row-parallel scatter over borrowed data: split `out` (`n` rows of
/// `row_len` f32s) into one contiguous chunk per worker and run
/// `f(t0, t1, chunk)` concurrently on the persistent [`global`] pool
/// workers behind a completion latch, with the last chunk executing on
/// the calling thread (satellite fix: this used to spawn scoped OS
/// threads per call — a measurable per-projection cost on the
/// non-batched streaming/decode path on many-core boxes).
///
/// This is the engine-side primitive for the tied logits head and the
/// FFN (rows are independent there). The call sites *borrow*
/// activations, so the enqueued jobs erase their borrow lifetime; the
/// latch (waited on even when unwinding) guarantees every job finishes
/// before this frame returns, which is what made scoped threads sound
/// too. Runs inline when `n < min_rows`, when only one worker is
/// configured, or on a pool worker (the batch level already owns the
/// cores then), so nesting is always deadlock- and oversubscription-
/// free. Each out element is written by exactly one chunk; parallel and
/// inline execution agree bitwise as long as `f`'s per-row output does
/// not depend on (t0, t1) — true of every kernel call site (each row is
/// an independent set of dots).
pub fn scatter_rows<F>(n: usize, row_len: usize, out: &mut [f32], min_rows: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Send + Sync,
{
    // PANIC-OK: caller contract, checked once at entry so the chunk
    // splitting below can never overrun `out`
    assert!(out.len() >= n * row_len, "scatter_rows: out too small");
    let threads = configured_threads();
    let pool = global();
    // `pool.saturated()`: the idle-aware inline fallback. scatter_rows
    // chunks queue FIFO on the shared pool; when one process both
    // trains and serves, a decode-path scatter would otherwise park
    // behind an entire training batch's row jobs (the streaming-latency
    // cliff in the ROADMAP). If every worker is already busy, running
    // inline starts immediately and costs at most the single-thread
    // compute we'd pay anyway after the queue drained.
    if n < min_rows.max(2) || threads < 2 || in_worker() || pool.saturated() {
        f(0, n, &mut out[..n * row_len]);
        return;
    }
    let nch = threads.min(n);
    let per = n.div_ceil(nch);
    let latch = Latch::new();
    let enqueued = Cell::new(0usize);
    // armed before the first enqueue: ANY unwind out of this frame —
    // a failed enqueue mid-loop or a panic in the final inline chunk —
    // first waits for every job that was actually sent
    let guard = LatchWait { latch: &latch, enqueued: &enqueued };
    let mut rest = &mut out[..n * row_len];
    let mut t0 = 0usize;
    let mut last: Option<(usize, usize, &mut [f32])> = None;
    while t0 < n {
        let t1 = (t0 + per).min(n);
        let (chunk, tail) = std::mem::take(&mut rest).split_at_mut((t1 - t0) * row_len);
        rest = tail;
        if t1 < n {
            let latch_r = &latch;
            let fref = &f;
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let msg = catch_unwind(AssertUnwindSafe(|| {
                    let _span = crate::obs::span("pool", "scatter_chunk");
                    fref(t0, t1, chunk)
                }))
                .err()
                .map(|p| panic_message(p.as_ref()));
                latch_r.done(msg);
            });
            // SAFETY: the job borrows `f`, the latch, and a disjoint
            // `out` chunk. The latch counts a job completed only after
            // its body (and every borrow) is done, and this frame never
            // returns — normally or unwinding — before waiting for all
            // `enqueued` jobs (the normal-path wait below, or the
            // `LatchWait` guard armed above), so the erased borrows
            // strictly outlive every job. `enqueued` is bumped only
            // after a successful send: a job that failed to enqueue is
            // dropped inside the failed send and never waited on. The
            // count-up latch invariant this rests on ("wait(enqueued)
            // returns only after every enqueued job body has fully
            // run, panicking or not") is model-checked exhaustively in
            // `model_check::scatter_latch_protocol_holds` below; the
            // unsafe scope is exactly this lifetime-erasing transmute.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job)
            };
            pool.execute_boxed(job);
            enqueued.set(enqueued.get() + 1);
        } else {
            last = Some((t0, t1, chunk));
        }
        t0 = t1;
    }
    if let Some((t0, t1, chunk)) = last {
        let _span = crate::obs::span("pool", "scatter_chunk");
        f(t0, t1, chunk); // final chunk on the calling thread
    }
    std::mem::forget(guard); // normal path: wait below, collecting panics
    let panics = latch.wait(enqueued.get());
    if !panics.is_empty() {
        // PANIC-OK: deliberate propagation — a chunk job panicked on a
        // worker; the unwind must surface on the calling thread
        panic!("{} scatter_rows job(s) panicked: {}", panics.len(), panics.join("; "));
    }
}

// Model-check port of the scatter_rows completion protocol — the seam
// the crate's only `unsafe` (the lifetime-erasing transmute above)
// depends on. Built and run with `RUSTFLAGS="--cfg model_check"`.
#[cfg(all(test, model_check))]
mod model_check {
    use super::*;
    use crate::util::chk;
    use crate::util::sync::atomic::{AtomicUsize, Ordering};

    /// The real `Latch`/`LatchWait` discipline, exercised under every
    /// interleaving (bounded DFS + random): two "workers" run erased
    /// job bodies and `done()`; the "caller" waits for exactly the
    /// enqueued count. The assertion is the borrow-liveness invariant
    /// scatter_rows erases lifetimes against: when `wait(target)`
    /// returns, every job body has fully run (so no borrow can dangle)
    /// and every panic message has been collected.
    #[test]
    fn scatter_latch_protocol_holds() {
        let report = chk::check(chk::Config::default(), || {
            let latch = Arc::new(Latch::new());
            let bodies_run = Arc::new(AtomicUsize::new(0));
            let mut handles = Vec::new();
            for i in 0..2u32 {
                let l = Arc::clone(&latch);
                let b = Arc::clone(&bodies_run);
                handles.push(chk::spawn(move || {
                    // modeled job body (the borrow the transmute erased)
                    b.fetch_add(1, Ordering::SeqCst);
                    // worker 1 models a panicking job: its message is
                    // collected, its completion still counted
                    l.done(if i == 1 { Some("job exploded".to_string()) } else { None });
                }));
            }
            let panics = latch.wait(2);
            assert_eq!(
                bodies_run.load(Ordering::SeqCst),
                2,
                "wait() returned while a job body (an erased borrow) was still live"
            );
            assert_eq!(panics, vec!["job exploded".to_string()]);
            for h in handles {
                h.join();
            }
        });
        report.assert_ok();
        assert!(report.dfs_complete, "latch protocol should be exhaustible at bound 2");
    }

    /// Mutant latch: `done()` bumps the count but never notifies —
    /// the lost-wakeup bug the real `Latch::done` guards against. The
    /// checker must find the schedule where the waiter blocks first
    /// and report it as a deadlock (pins the checker itself).
    struct SilentLatch {
        state: Mutex<usize>,
        cv: Condvar,
    }

    impl SilentLatch {
        fn wait(&self, target: usize) {
            let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
            while *st < target {
                st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        }

        fn done(&self) {
            *self.state.lock().unwrap_or_else(|e| e.into_inner()) += 1;
            // MUTANT: missing self.cv.notify_all()
        }
    }

    #[test]
    fn checker_catches_latch_without_notify() {
        let report = chk::check(chk::Config::default(), || {
            let latch = Arc::new(SilentLatch { state: Mutex::new(0), cv: Condvar::new() });
            let l = Arc::clone(&latch);
            let h = chk::spawn(move || l.done());
            latch.wait(1);
            h.join();
        });
        let f = report.assert_fails();
        assert!(f.message.contains("deadlock"), "expected a lost-wakeup deadlock: {}", f.message);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_order() {
        let pool = ThreadPool::new(3);
        let out = parallel_map(&pool, 20, |i| i * i);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn join_idempotent() {
        let pool = ThreadPool::new(2);
        pool.join();
        pool.execute(|| {});
        pool.join();
        pool.join();
    }

    #[test]
    fn panicking_job_is_surfaced_not_deadlocked() {
        // the satellite seam: before the drop-guard fix this join hung
        // forever (pending never decremented) or poisoned the mutex
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for i in 0..8 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                if i == 3 {
                    panic!("job {i} exploded");
                }
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        let err = pool.try_join().expect_err("panic must surface");
        assert!(err.contains("job 3 exploded"), "message lost: {err}");
        assert_eq!(counter.load(Ordering::SeqCst), 7, "other jobs must complete");

        // the pool (and its workers) must remain fully usable afterwards
        let c = Arc::clone(&counter);
        pool.execute(move || {
            c.fetch_add(10, Ordering::SeqCst);
        });
        pool.try_join().expect("panic report must clear the error state");
        assert_eq!(counter.load(Ordering::SeqCst), 17);
    }

    #[test]
    fn parallel_map_reraises_job_panic_on_caller() {
        let pool = ThreadPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            parallel_map(&pool, 6, |i| {
                if i == 2 {
                    panic!("row 2 bad");
                }
                i
            })
        }));
        let msg = panic_message(caught.expect_err("must re-raise").as_ref());
        assert!(msg.contains("row 2 bad"), "message lost: {msg}");
        // and again: the pool survives
        assert_eq!(parallel_map(&pool, 4, |i| i + 1), vec![1, 2, 3, 4]);
    }

    #[test]
    fn nested_parallel_map_runs_inline_without_deadlock() {
        let pool = ThreadPool::new(2);
        // 4 outer jobs on 2 workers, each fanning out again: the nested
        // calls must run inline (in_worker) or this join never returns
        let out = parallel_map(&pool, 4, |i| {
            assert!(in_worker());
            parallel_map(global(), 3, move |j| i * 10 + j)
        });
        assert_eq!(out[2], vec![20, 21, 22]);
    }

    #[test]
    fn threads_from_env_override_rules() {
        // the STLT_THREADS parse seam, kept pure so tests don't race on
        // the process environment (configured_threads memoizes once)
        assert_eq!(threads_from(Some("6"), 2), 6);
        assert_eq!(threads_from(Some(" 3 "), 2), 3);
        assert_eq!(threads_from(None, 4), 4);
        assert_eq!(threads_from(Some(""), 4), 4);
        assert_eq!(threads_from(Some("zero"), 4), 4);
        assert_eq!(threads_from(Some("0"), 4), 4, "0 workers is nonsense");
        assert_eq!(threads_from(None, 0), 1, "floor at one worker");
    }

    #[test]
    fn scatter_rows_runs_on_persistent_workers() {
        // the satellite seam: chunks execute on global() pool workers
        // (in_worker), not on freshly spawned scoped threads — except
        // the final chunk, which stays on the caller. Concurrent tests
        // can transiently saturate the shared pool (which now triggers
        // the idle-aware inline fallback), so retry until a fan-out
        // actually happens.
        if configured_threads() < 2 {
            return; // single-core box: scatter is documented-inline
        }
        use std::time::{Duration, Instant};
        let n = 64usize;
        let row_len = 2usize;
        let mut fanned_out = false;
        let deadline = Instant::now() + Duration::from_secs(10);
        while Instant::now() < deadline {
            let mut out = vec![0.0f32; n * row_len];
            let worker_chunks = AtomicUsize::new(0);
            let caller_chunks = AtomicUsize::new(0);
            scatter_rows(n, row_len, &mut out, 2, |t0, _t1, chunk| {
                if in_worker() {
                    worker_chunks.fetch_add(1, Ordering::SeqCst);
                } else {
                    caller_chunks.fetch_add(1, Ordering::SeqCst);
                }
                for (r, row) in chunk.chunks_exact_mut(row_len).enumerate() {
                    row.fill((t0 + r) as f32);
                }
            });
            for t in 0..n {
                assert_eq!(out[t * row_len], t as f32);
            }
            if worker_chunks.load(Ordering::SeqCst) >= 1 {
                assert_eq!(
                    caller_chunks.load(Ordering::SeqCst),
                    1,
                    "final chunk runs on the caller"
                );
                fanned_out = true;
                break;
            }
            // the shared pool may be transiently saturated by sibling
            // tests (forcing the inline fallback); back off and retry
            thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(fanned_out, "no scatter call ever reached a pool worker");
    }

    #[test]
    fn scatter_rows_propagates_worker_panic_and_pool_survives() {
        if configured_threads() < 2 {
            return;
        }
        let n = 64usize;
        let mut out = vec![0.0f32; n];
        let caught = catch_unwind(AssertUnwindSafe(|| {
            scatter_rows(n, 1, &mut out, 2, |t0, _t1, _chunk| {
                if t0 == 0 {
                    panic!("chunk at {t0} exploded");
                }
            });
        }));
        let msg = panic_message(caught.expect_err("panic must reach the caller").as_ref());
        assert!(msg.contains("exploded"), "message lost: {msg}");
        // the global pool must stay fully usable (worker survived, no
        // stuck latch, no poisoned queue)
        let mut out = vec![0.0f32; n];
        scatter_rows(n, 1, &mut out, 2, |t0, t1, chunk| {
            for (r, v) in chunk.iter_mut().enumerate() {
                *v = (t0 + r) as f32;
            }
            assert!(t1 <= n);
        });
        assert_eq!(out[n - 1], (n - 1) as f32);
        assert!(global().try_join().is_ok(), "scatter panics must not leak into pool joins");
    }

    #[test]
    fn pending_tracks_queue_depth() {
        let pool = ThreadPool::new(2);
        assert_eq!(pool.pending(), 0);
        assert!(!pool.saturated());
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        for _ in 0..3 {
            let g = Arc::clone(&gate);
            pool.execute(move || {
                let (m, cv) = &*g;
                let mut open = m.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            });
        }
        assert_eq!(pool.pending(), 3);
        assert!(pool.saturated(), "3 blocked jobs on 2 workers is saturated");
        {
            let (m, cv) = &*gate;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        pool.join();
        assert_eq!(pool.pending(), 0);
    }

    #[test]
    fn scatter_not_starved_by_saturating_batch_job() {
        // the fairness satellite seam: with every global worker parked
        // on a long "training batch" job, a decode-path scatter_rows
        // must fall back inline instead of queueing behind them. Before
        // the idle-aware fallback this took >= the blockers' duration.
        use std::time::{Duration, Instant};
        let pool = global();
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let held = Arc::new(AtomicUsize::new(0));
        for _ in 0..pool.threads() {
            let g = Arc::clone(&gate);
            let h = Arc::clone(&held);
            pool.execute(move || {
                h.fetch_add(1, Ordering::SeqCst);
                let (m, cv) = &*g;
                let mut open = m.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            });
        }
        // wait until the blockers actually occupy the workers
        let t0 = Instant::now();
        while held.load(Ordering::SeqCst) < pool.threads()
            && t0.elapsed() < Duration::from_secs(5)
        {
            std::thread::yield_now();
        }
        assert!(pool.saturated(), "blockers must saturate the pool");
        let n = 64usize;
        let mut out = vec![0.0f32; n];
        let t0 = Instant::now();
        let ran_on_worker = AtomicUsize::new(0);
        scatter_rows(n, 1, &mut out, 2, |t0c, _t1, chunk| {
            if in_worker() {
                ran_on_worker.fetch_add(1, Ordering::SeqCst);
            }
            for (r, v) in chunk.iter_mut().enumerate() {
                *v = (t0c + r) as f32;
            }
        });
        let elapsed = t0.elapsed();
        // release the blockers before asserting, so a failure can't
        // leave the shared pool wedged for other tests
        {
            let (m, cv) = &*gate;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        let _ = pool.try_join();
        assert_eq!(ran_on_worker.load(Ordering::SeqCst), 0, "must run inline when saturated");
        assert!(
            elapsed < Duration::from_secs(2),
            "decode scatter starved behind batch jobs: {elapsed:?}"
        );
        for (t, v) in out.iter().enumerate() {
            assert_eq!(*v, t as f32);
        }
    }

    #[test]
    fn scatter_rows_covers_every_chunk_exactly_once() {
        for n in [0usize, 1, 2, 15, 16, 33] {
            let row_len = 3;
            let mut out = vec![0.0f32; n * row_len];
            scatter_rows(n, row_len, &mut out, 16, |t0, t1, chunk| {
                assert_eq!(chunk.len(), (t1 - t0) * row_len);
                for (r, row) in chunk.chunks_exact_mut(row_len).enumerate() {
                    for v in row.iter_mut() {
                        *v += (t0 + r) as f32; // += catches double-writes
                    }
                }
            });
            for t in 0..n {
                for j in 0..row_len {
                    assert_eq!(out[t * row_len + j], t as f32, "row {t} col {j} (n={n})");
                }
            }
        }
    }
}
