//! Minimal JSON parser/writer (no serde offline) — sufficient for the
//! artifact manifest (artifacts/manifest.json) and metrics dumps.
//!
//! Supports the full JSON value grammar; numbers are kept as f64 with an
//! i64 fast path via `as_i64`. Not streaming: files here are < 100 KB.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("c").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":{"m.train":{"file":"m.hlo.txt","inputs":[{"dtype":"float32","shape":[3,4]}],"param_count":123}},"version":1}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"version":1,"entries":{"lm.train":{"file":"lm.train.hlo.txt",
            "kind":"train_step","param_count":31458,
            "inputs":[{"dtype":"float32","shape":[31458]},{"dtype":"int32","shape":[]}],
            "outputs":[{"dtype":"float32","shape":[31458]}],
            "config":{"arch":"stlt","d_model":64,"adaptive":true}}}}"#;
        let j = Json::parse(src).unwrap();
        let e = j.get("entries").unwrap().get("lm.train").unwrap();
        assert_eq!(e.get("kind").unwrap().as_str(), Some("train_step"));
        assert_eq!(e.get("param_count").unwrap().as_i64(), Some(31458));
        assert_eq!(e.get("config").unwrap().get("arch").unwrap().as_str(), Some("stlt"));
    }
}
