//! Deterministic concurrency model checker ("chk").
//!
//! Runs a closure-defined multi-thread protocol under a cooperative
//! scheduler: every model thread is a real OS thread, but exactly one
//! runs at a time, and control transfers only at *visible operations*
//! (lock/unlock, condvar wait/notify, channel send/recv, atomic ops —
//! the primitives in [`prim`], which `util::sync` re-exports when the
//! crate is built with `--cfg model_check`). Because every scheduling
//! decision happens at an explicit choice point, the checker can
//!
//! - enumerate interleavings exhaustively via stateless DFS with a
//!   *bounded number of preemptions* (CHESS-style: most concurrency
//!   bugs manifest with <= 2 preemptions, and bounding keeps the
//!   schedule space tractable),
//! - follow that with splitmix64-seeded random schedules at an
//!   unbounded preemption budget to probe beyond the DFS bound,
//! - detect deadlock and lost wakeups directly: if no thread is
//!   runnable, none is waiting on a modeled timeout, and not all have
//!   finished, the schedule is stuck and is reported with every
//!   blocked thread's operation,
//! - report any panic (assertion failure) inside a model thread as a
//!   failing schedule together with the choice trace that produced it.
//!
//! Protocol closures must be deterministic: given the same schedule
//! they must perform the same sequence of visible operations (no wall
//! clock, no OS randomness, no HashMap-iteration-order-dependent
//! branching). Timed waits (`Condvar::wait_timeout`) are modeled
//! logically: a timeout can only fire when the system is otherwise
//! quiescent, which keeps the state space small and matches the
//! "timeouts are a liveness escape hatch" role they play in the
//! serving substrate. `prim` locks must not be acquired inside `Drop`
//! impls of protocol state (drops run during unwinding, where the
//! scheduler refuses to park a thread).
//!
//! This module is always compiled (its own unit tests run in tier-1);
//! only the re-export through `util::sync` is gated on `model_check`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread;

/// Knobs for [`check`]. `Default` is sized for protocol tests with
/// 2-4 threads and a handful of operations each.
#[derive(Clone, Debug)]
pub struct Config {
    /// Preemption budget for the exhaustive DFS phase: scheduling away
    /// from a still-runnable thread costs one preemption; once the
    /// budget is spent the running thread continues until it blocks or
    /// finishes. 2 catches the overwhelming majority of real bugs.
    pub preemption_bound: usize,
    /// Hard cap on DFS schedules (the DFS stops early if the bounded
    /// space is exhausted first, which `Report::dfs_complete` records).
    pub max_schedules: usize,
    /// Number of random schedules to run after DFS, each with an
    /// unbounded preemption budget.
    pub random_schedules: usize,
    /// Seed for the splitmix64 stream that drives random schedules.
    pub seed: u64,
    /// Per-schedule step cap: exceeding it is reported as a livelock.
    pub max_steps: usize,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            preemption_bound: 2,
            max_schedules: 20_000,
            random_schedules: 64,
            seed: 0x5113_b0c4_u64,
            max_steps: 20_000,
        }
    }
}

/// A failing schedule: what went wrong plus the choice trace
/// (`t<id>:<op>` per scheduling decision) that reproduces it.
#[derive(Clone, Debug)]
pub struct Failure {
    pub message: String,
    pub trace: String,
}

/// Outcome of [`check`].
#[derive(Clone, Debug)]
pub struct Report {
    /// Total schedules executed (DFS + random).
    pub schedules: usize,
    /// True iff the DFS exhausted every schedule at the preemption
    /// bound (rather than stopping at `max_schedules`).
    pub dfs_complete: bool,
    pub failure: Option<Failure>,
}

impl Report {
    /// Panics (failing the enclosing test) if any schedule failed.
    pub fn assert_ok(&self) {
        if let Some(f) = &self.failure {
            panic!(
                "model check failed after {} schedule(s): {}\nschedule: {}",
                self.schedules, f.message, f.trace
            );
        }
    }

    /// Returns the failure, panicking if every schedule passed — used
    /// to pin the checker itself against deliberately-broken mutants.
    pub fn assert_fails(&self) -> &Failure {
        match &self.failure {
            Some(f) => f,
            None => panic!(
                "model check unexpectedly passed all {} schedule(s) (dfs_complete={})",
                self.schedules, self.dfs_complete
            ),
        }
    }
}

/// splitmix64: tiny, high-quality 64-bit PRNG step (public domain
/// constants; same finalizer the session router uses for placement
/// hashing). Advances `state` and returns the next value.
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

// ---------------------------------------------------------------------------
// Scheduler internals
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Status {
    Runnable,
    Running,
    Blocked,
    Finished,
}

struct ThreadSt {
    status: Status,
    /// Object this thread is blocked on (valid while `Blocked`).
    blocked_on: u64,
    /// Blocked with a timeout escape (a modeled `wait_timeout`).
    timed: bool,
    /// Set by the controller when a timed block is woken by its
    /// timeout firing rather than a real notify.
    woke_by_timeout: bool,
    /// The operation this thread is at (for traces and deadlock
    /// reports).
    desc: &'static str,
    /// Object joiners block on until this thread finishes.
    join_obj: u64,
}

impl ThreadSt {
    fn new(desc: &'static str) -> ThreadSt {
        ThreadSt {
            status: Status::Runnable,
            blocked_on: 0,
            timed: false,
            woke_by_timeout: false,
            desc,
            join_obj: fresh_obj(),
        }
    }
}

struct ChoicePoint {
    /// Number of candidates at this decision.
    n: usize,
    /// Which one was taken (index into the sorted candidate list).
    chosen: usize,
    tid: usize,
    desc: &'static str,
}

struct SchedState {
    threads: Vec<ThreadSt>,
    handles: Vec<Option<thread::JoinHandle<()>>>,
    /// The one thread currently allowed to run (None while the
    /// controller is choosing).
    active: Option<usize>,
    /// Last scheduled thread, for preemption accounting.
    prev: Option<usize>,
    preemptions: usize,
    trace: Vec<ChoicePoint>,
    steps: usize,
    failure: Option<String>,
    /// Set by the controller to tear the schedule down: parked threads
    /// wake, unwind with `ChkAbort`, and finish.
    aborting: bool,
}

pub(crate) struct Session {
    st: Mutex<SchedState>,
    cv: Condvar,
}

/// Panic payload used to unwind model threads during teardown; the
/// thread wrapper swallows it without recording a failure.
struct ChkAbort;

thread_local! {
    static CTX: std::cell::RefCell<Option<(Arc<Session>, usize)>> =
        std::cell::RefCell::new(None);
}

/// The (session, tid) of the calling thread, if it is a model thread.
pub(crate) fn session() -> Option<(Arc<Session>, usize)> {
    CTX.with(|c| c.borrow().clone())
}

/// True iff the calling thread is inside a model-check session; the
/// `prim` wrappers use this to fall back to plain `std::sync`.
pub(crate) fn in_session() -> bool {
    CTX.with(|c| c.borrow().is_some())
}

/// If on a model thread, hand the scheduler a decision point labelled
/// `desc`; otherwise a no-op. Used by the atomic wrappers.
pub(crate) fn op_point(desc: &'static str) {
    if let Some((sess, me)) = session() {
        sess.yield_op(me, desc);
    }
}

static NEXT_OBJ: AtomicU64 = AtomicU64::new(1);

/// Fresh process-unique id for a blockable object (mutex, condvar,
/// channel, join handle).
pub(crate) fn fresh_obj() -> u64 {
    NEXT_OBJ.fetch_add(1, Ordering::SeqCst)
}

impl Session {
    fn lock(&self) -> MutexGuard<'_, SchedState> {
        self.st.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Core control transfer: mark this thread Runnable (plain yield)
    /// or Blocked on an object, wake the controller, and sleep until
    /// scheduled again. Returns true iff a timed block was ended by
    /// its timeout firing. No-op while unwinding (drops must never
    /// park; the schedule is ending anyway).
    fn deschedule(&self, tid: usize, desc: &'static str, block: Option<(u64, bool)>) -> bool {
        if thread::panicking() {
            return false;
        }
        let mut st = self.lock();
        if st.aborting {
            drop(st);
            std::panic::panic_any(ChkAbort);
        }
        {
            let t = &mut st.threads[tid];
            t.desc = desc;
            match block {
                Some((obj, timed)) => {
                    t.status = Status::Blocked;
                    t.blocked_on = obj;
                    t.timed = timed;
                    t.woke_by_timeout = false;
                }
                None => t.status = Status::Runnable,
            }
        }
        st.active = None;
        self.cv.notify_all();
        loop {
            if st.aborting {
                drop(st);
                std::panic::panic_any(ChkAbort);
            }
            if st.active == Some(tid) {
                break;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        let t = &mut st.threads[tid];
        t.status = Status::Running;
        let fired = t.woke_by_timeout;
        t.woke_by_timeout = false;
        fired
    }

    /// A plain scheduling point before a visible operation.
    pub(crate) fn yield_op(&self, tid: usize, desc: &'static str) {
        self.deschedule(tid, desc, None);
    }

    /// Park until `obj` is signalled (or, when `timed`, until the
    /// controller fires the timeout). Returns true iff timed out.
    pub(crate) fn block_on(&self, tid: usize, obj: u64, desc: &'static str, timed: bool) -> bool {
        self.deschedule(tid, desc, Some((obj, timed)))
    }

    /// Make every thread blocked on `obj` runnable again.
    pub(crate) fn unblock_all(&self, obj: u64) {
        let mut st = self.lock();
        for t in st.threads.iter_mut() {
            if t.status == Status::Blocked && t.blocked_on == obj {
                t.status = Status::Runnable;
            }
        }
    }

    /// Make the lowest-tid thread blocked on `obj` runnable again.
    pub(crate) fn unblock_one(&self, obj: u64) {
        let mut st = self.lock();
        for t in st.threads.iter_mut() {
            if t.status == Status::Blocked && t.blocked_on == obj {
                t.status = Status::Runnable;
                break;
            }
        }
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Body of every model thread: register TLS, wait to be scheduled the
/// first time, run the closure, then mark Finished and wake joiners
/// and the controller.
fn thread_main<F: FnOnce()>(sess: Arc<Session>, tid: usize, f: F) {
    CTX.with(|c| *c.borrow_mut() = Some((Arc::clone(&sess), tid)));
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        {
            let mut st = sess.lock();
            loop {
                if st.aborting {
                    drop(st);
                    std::panic::panic_any(ChkAbort);
                }
                if st.active == Some(tid) {
                    break;
                }
                st = sess.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            st.threads[tid].status = Status::Running;
        }
        f();
    }));
    let mut st = sess.lock();
    if let Err(p) = outcome {
        if p.downcast_ref::<ChkAbort>().is_none() && st.failure.is_none() {
            st.failure = Some(format!("thread t{tid} panicked: {}", panic_message(&*p)));
        }
    }
    let join_obj = st.threads[tid].join_obj;
    st.threads[tid].status = Status::Finished;
    for t in st.threads.iter_mut() {
        if t.status == Status::Blocked && t.blocked_on == join_obj {
            t.status = Status::Runnable;
        }
    }
    if st.active == Some(tid) {
        st.active = None;
    }
    sess.cv.notify_all();
    CTX.with(|c| *c.borrow_mut() = None);
}

/// Handle to a model thread spawned with [`spawn`].
pub struct JoinHandle {
    sess: Arc<Session>,
    tid: usize,
}

impl JoinHandle {
    /// Block (as a modeled operation) until the thread finishes. Any
    /// panic in the thread is already recorded as a schedule failure,
    /// so join itself never propagates one.
    pub fn join(self) {
        let (sess, me) = session().expect("chk::JoinHandle::join outside a model-check session");
        sess.yield_op(me, "join");
        loop {
            let (done, obj) = {
                let st = sess.lock();
                let t = &st.threads[self.tid];
                (t.status == Status::Finished, t.join_obj)
            };
            if done {
                return;
            }
            sess.block_on(me, obj, "join", false);
        }
    }
}

/// Spawn a model thread inside the current session. Panics if called
/// from outside a session (model threads only exist under [`check`]).
pub fn spawn<F: FnOnce() + Send + 'static>(f: F) -> JoinHandle {
    let (sess, me) = session().expect("chk::spawn outside a model-check session");
    let tid;
    {
        let mut st = sess.lock();
        tid = st.threads.len();
        st.threads.push(ThreadSt::new("spawned"));
        let s2 = Arc::clone(&sess);
        let h = thread::Builder::new()
            .name(format!("chk-{tid}"))
            .spawn(move || thread_main(s2, tid, f))
            .expect("spawn chk model thread");
        st.handles.push(Some(h));
    }
    // Spawning is itself a visible step: give the scheduler the chance
    // to run the child before the parent's next operation.
    sess.yield_op(me, "spawn");
    JoinHandle { sess, tid }
}

struct RunOutcome {
    trace: Vec<ChoicePoint>,
    failure: Option<String>,
}

/// Execute one schedule: `replay` pins the first choices (DFS), then
/// `rng` (if any) picks randomly, then the default is candidate 0.
fn run_one(
    cfg: &Config,
    f: Arc<dyn Fn() + Send + Sync>,
    replay: &[usize],
    mut rng: Option<u64>,
    bound: usize,
) -> RunOutcome {
    let sess = Arc::new(Session {
        st: Mutex::new(SchedState {
            threads: vec![ThreadSt::new("start")],
            handles: Vec::new(),
            active: None,
            prev: None,
            preemptions: 0,
            trace: Vec::new(),
            steps: 0,
            failure: None,
            aborting: false,
        }),
        cv: Condvar::new(),
    });
    {
        let mut st = sess.lock();
        let s2 = Arc::clone(&sess);
        let g = Arc::clone(&f);
        let h = thread::Builder::new()
            .name("chk-0".to_string())
            .spawn(move || thread_main(s2, 0, move || g()))
            .expect("spawn chk root thread");
        st.handles.push(Some(h));
    }

    let mut depth = 0usize;
    let mut st = sess.lock();
    loop {
        while st.active.is_some() {
            st = sess.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if st.failure.is_some() {
            break;
        }
        let runnable: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| t.status == Status::Runnable)
            .map(|(i, _)| i)
            .collect();
        // (cands, true) = schedule one of them; (cands, false) = fire
        // the timeout of one of them (only when nothing is runnable).
        let (cands, run) = if runnable.is_empty() {
            if st.threads.iter().all(|t| t.status == Status::Finished) {
                break; // schedule complete
            }
            let timed: Vec<usize> = st
                .threads
                .iter()
                .enumerate()
                .filter(|(_, t)| t.status == Status::Blocked && t.timed)
                .map(|(i, _)| i)
                .collect();
            if timed.is_empty() {
                let stuck: Vec<String> = st
                    .threads
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| t.status == Status::Blocked)
                    .map(|(i, t)| format!("t{i} in {}", t.desc))
                    .collect();
                st.failure = Some(format!(
                    "deadlock (possible lost wakeup): no runnable thread; blocked: {}",
                    stuck.join(", ")
                ));
                break;
            }
            (timed, false)
        } else {
            let mut cands = runnable;
            if let Some(p) = st.prev {
                // Preemption bounding: once the budget is spent, a
                // still-runnable previous thread keeps running.
                if st.preemptions >= bound && cands.contains(&p) {
                    cands = vec![p];
                }
            }
            (cands, true)
        };
        let chosen = if depth < replay.len() {
            replay[depth].min(cands.len() - 1)
        } else if let Some(s) = rng.as_mut() {
            (splitmix64(s) % cands.len() as u64) as usize
        } else {
            0
        };
        depth += 1;
        let tid = cands[chosen];
        if !run {
            st.trace.push(ChoicePoint { n: cands.len(), chosen, tid, desc: "timeout" });
            let t = &mut st.threads[tid];
            t.status = Status::Runnable;
            t.woke_by_timeout = true;
            continue;
        }
        st.trace.push(ChoicePoint { n: cands.len(), chosen, tid, desc: st.threads[tid].desc });
        if let Some(p) = st.prev {
            if p != tid && st.threads[p].status == Status::Runnable {
                st.preemptions += 1;
            }
        }
        st.steps += 1;
        if st.steps > cfg.max_steps {
            st.failure = Some(format!(
                "exceeded max_steps={} (livelock or non-terminating protocol)",
                cfg.max_steps
            ));
            break;
        }
        st.active = Some(tid);
        st.prev = Some(tid);
        sess.cv.notify_all();
    }
    // Teardown: wake every parked thread; they unwind with ChkAbort.
    st.aborting = true;
    sess.cv.notify_all();
    while !st.threads.iter().all(|t| t.status == Status::Finished) {
        st = sess.cv.wait(st).unwrap_or_else(|e| e.into_inner());
    }
    let failure = st.failure.take();
    let trace = std::mem::take(&mut st.trace);
    let handles: Vec<_> = st.handles.iter_mut().map(|h| h.take()).collect();
    drop(st);
    for h in handles.into_iter().flatten() {
        let _ = h.join();
    }
    RunOutcome { trace, failure }
}

fn render_trace(trace: &[ChoicePoint]) -> String {
    trace
        .iter()
        .map(|c| format!("t{}:{}", c.tid, c.desc))
        .collect::<Vec<_>>()
        .join(" -> ")
}

/// Model-check `protocol`: exhaustive bounded-preemption DFS followed
/// by random schedules. The closure runs once per schedule as model
/// thread t0 and may [`spawn`] further model threads; any panic,
/// deadlock, lost wakeup, or livelock in any schedule is returned as a
/// [`Failure`] with its reproducing choice trace.
pub fn check<F>(cfg: Config, protocol: F) -> Report
where
    F: Fn() + Send + Sync + 'static,
{
    let f: Arc<dyn Fn() + Send + Sync> = Arc::new(protocol);
    let mut schedules = 0usize;
    let mut dfs_complete = false;
    let mut replay: Vec<usize> = Vec::new();
    while schedules < cfg.max_schedules {
        let out = run_one(&cfg, Arc::clone(&f), &replay, None, cfg.preemption_bound);
        schedules += 1;
        if let Some(message) = out.failure {
            return Report {
                schedules,
                dfs_complete: false,
                failure: Some(Failure { message, trace: render_trace(&out.trace) }),
            };
        }
        // Stateless DFS backtrack: bump the deepest choice that still
        // has an unexplored sibling; done when none remains.
        let mut tr = out.trace;
        loop {
            match tr.pop() {
                None => {
                    dfs_complete = true;
                    break;
                }
                Some(cp) if cp.chosen + 1 < cp.n => {
                    replay.clear();
                    replay.extend(tr.iter().map(|c| c.chosen));
                    replay.push(cp.chosen + 1);
                    break;
                }
                Some(_) => {}
            }
        }
        if dfs_complete {
            break;
        }
    }
    // Random phase: unbounded preemptions probe beyond the DFS bound.
    let mut seed = cfg.seed;
    for _ in 0..cfg.random_schedules {
        let s = splitmix64(&mut seed);
        let out = run_one(&cfg, Arc::clone(&f), &[], Some(s), usize::MAX);
        schedules += 1;
        if let Some(message) = out.failure {
            return Report {
                schedules,
                dfs_complete,
                failure: Some(Failure { message, trace: render_trace(&out.trace) }),
            };
        }
    }
    Report { schedules, dfs_complete, failure: None }
}

// ---------------------------------------------------------------------------
// prim: model-aware drop-ins for the std::sync primitives the repo uses
// ---------------------------------------------------------------------------

/// Model-aware counterparts of the `std::sync` primitives the codebase
/// uses. On a model thread every operation is a scheduling point and
/// blocking is simulated; on any other thread they delegate straight
/// to `std` (so production code built with `--cfg model_check` still
/// behaves normally outside sessions). `util::sync` re-exports these
/// under `model_check`; normal builds re-export `std::sync` itself.
pub mod prim {
    use std::ops::{Deref, DerefMut};
    use std::sync::{LockResult, PoisonError, TryLockError};
    use std::time::Duration;

    use super::{fresh_obj, session};

    /// Mirror of `std::sync::WaitTimeoutResult` (std's has no public
    /// constructor, so the modeled `wait_timeout` needs its own).
    pub struct WaitTimeoutResult(bool);

    impl WaitTimeoutResult {
        pub fn timed_out(&self) -> bool {
            self.0
        }
    }

    /// Lazily-assigned object id: `const fn new` parity with std means
    /// ids cannot be drawn at construction, so 0 marks "unassigned"
    /// and the first operation claims one (`fresh_obj` never returns
    /// 0). Id *values* never influence scheduling decisions — they
    /// only match blockers to wakers — so lazy assignment keeps
    /// schedules deterministic.
    fn lazy_obj_id(cell: &std::sync::atomic::AtomicU64) -> u64 {
        use std::sync::atomic::Ordering::SeqCst;
        let v = cell.load(SeqCst);
        if v != 0 {
            return v;
        }
        let n = fresh_obj();
        match cell.compare_exchange(0, n, SeqCst, SeqCst) {
            Ok(_) => n,
            Err(cur) => cur,
        }
    }

    pub struct Mutex<T> {
        id: std::sync::atomic::AtomicU64,
        /// The *model* ownership flag; `data`'s own lock is then
        /// uncontended by construction (one model thread runs at a
        /// time and only the flag holder touches it).
        held: std::sync::atomic::AtomicBool,
        data: std::sync::Mutex<T>,
    }

    pub struct MutexGuard<'a, T> {
        lock: &'a Mutex<T>,
        inner: Option<std::sync::MutexGuard<'a, T>>,
        /// Acquired through the model (release must signal it).
        modeled: bool,
    }

    impl<T> Mutex<T> {
        pub const fn new(t: T) -> Mutex<T> {
            Mutex {
                id: std::sync::atomic::AtomicU64::new(0),
                held: std::sync::atomic::AtomicBool::new(false),
                data: std::sync::Mutex::new(t),
            }
        }

        fn obj_id(&self) -> u64 {
            lazy_obj_id(&self.id)
        }

        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            match session() {
                None => match self.data.lock() {
                    Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g), modeled: false }),
                    Err(p) => Err(PoisonError::new(MutexGuard {
                        lock: self,
                        inner: Some(p.into_inner()),
                        modeled: false,
                    })),
                },
                Some((sess, me)) => {
                    sess.yield_op(me, "Mutex::lock");
                    while self.held.swap(true, std::sync::atomic::Ordering::SeqCst) {
                        sess.block_on(me, self.obj_id(), "Mutex::lock", false);
                    }
                    Ok(MutexGuard { lock: self, inner: Some(self.take_data()), modeled: true })
                }
            }
        }

        pub fn into_inner(self) -> LockResult<T> {
            self.data.into_inner()
        }

        /// Grab the std lock after winning the model flag; cannot
        /// contend, so try_lock only "fails" with poison.
        fn take_data(&self) -> std::sync::MutexGuard<'_, T> {
            match self.data.try_lock() {
                Ok(g) => g,
                Err(TryLockError::Poisoned(p)) => p.into_inner(),
                Err(TryLockError::WouldBlock) => {
                    unreachable!("chk Mutex: data locked without the model flag")
                }
            }
        }
    }

    impl<T> Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().expect("chk MutexGuard used after release")
        }
    }

    impl<T> DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().expect("chk MutexGuard used after release")
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            let _ = self.inner.take();
            if self.modeled {
                self.lock.held.store(false, std::sync::atomic::Ordering::SeqCst);
                if let Some((sess, me)) = session() {
                    sess.unblock_all(self.lock.obj_id());
                    // A scheduling point after release — but never
                    // park while unwinding (deschedule no-ops then).
                    sess.yield_op(me, "Mutex::unlock");
                }
            }
        }
    }

    pub struct Condvar {
        id: std::sync::atomic::AtomicU64,
        inner: std::sync::Condvar,
    }

    impl Default for Condvar {
        fn default() -> Condvar {
            Condvar::new()
        }
    }

    impl Condvar {
        pub const fn new() -> Condvar {
            Condvar { id: std::sync::atomic::AtomicU64::new(0), inner: std::sync::Condvar::new() }
        }

        fn obj_id(&self) -> u64 {
            lazy_obj_id(&self.id)
        }

        pub fn notify_one(&self) {
            match session() {
                Some((sess, me)) => {
                    sess.yield_op(me, "Condvar::notify_one");
                    sess.unblock_one(self.obj_id());
                }
                None => self.inner.notify_one(),
            }
        }

        pub fn notify_all(&self) {
            match session() {
                Some((sess, me)) => {
                    sess.yield_op(me, "Condvar::notify_all");
                    sess.unblock_all(self.obj_id());
                }
                None => self.inner.notify_all(),
            }
        }

        pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            Ok(self.wait_inner(guard, false).0)
        }

        pub fn wait_timeout<'a, T>(
            &self,
            guard: MutexGuard<'a, T>,
            dur: Duration,
        ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
            if guard.modeled {
                // Modeled timeouts are logical: the controller fires
                // one only when the system is otherwise quiescent, so
                // the duration itself is irrelevant to the schedule.
                let _ = dur;
                let (g, fired) = self.wait_inner(guard, true);
                return Ok((g, WaitTimeoutResult(fired)));
            }
            let lock = guard.lock;
            let inner = Self::release_std(guard);
            match self.inner.wait_timeout(inner, dur) {
                Ok((g, r)) => Ok((
                    MutexGuard { lock, inner: Some(g), modeled: false },
                    WaitTimeoutResult(r.timed_out()),
                )),
                Err(p) => {
                    let (g, r) = p.into_inner();
                    Err(PoisonError::new((
                        MutexGuard { lock, inner: Some(g), modeled: false },
                        WaitTimeoutResult(r.timed_out()),
                    )))
                }
            }
        }

        /// Shared wait path; returns (reacquired guard, timed_out).
        fn wait_inner<'a, T>(&self, guard: MutexGuard<'a, T>, timed: bool) -> (MutexGuard<'a, T>, bool) {
            if !guard.modeled {
                let lock = guard.lock;
                let inner = Self::release_std(guard);
                let g = self.inner.wait(inner).unwrap_or_else(|e| e.into_inner());
                return (MutexGuard { lock, inner: Some(g), modeled: false }, false);
            }
            let (sess, me) = session().expect("modeled MutexGuard waited outside its session");
            let lock = guard.lock;
            // Atomic release-and-park: drop the data guard, clear the
            // model flag, wake lock waiters, and block on the condvar
            // — all without an intervening scheduling point, so the
            // model itself cannot miss a wakeup between them.
            let mut guard = guard;
            let _ = guard.inner.take();
            lock.held.store(false, std::sync::atomic::Ordering::SeqCst);
            sess.unblock_all(lock.obj_id());
            std::mem::forget(guard);
            let fired = sess.block_on(me, self.obj_id(), "Condvar::wait", timed);
            // Reacquire the lock (a fresh modeled acquisition).
            while lock.held.swap(true, std::sync::atomic::Ordering::SeqCst) {
                sess.block_on(me, lock.obj_id(), "Mutex::relock", false);
            }
            (MutexGuard { lock, inner: Some(lock.take_data()), modeled: true }, fired)
        }

        /// Extract the std guard from an unmodeled wrapper without
        /// running its Drop.
        fn release_std<T>(mut guard: MutexGuard<'_, T>) -> std::sync::MutexGuard<'_, T> {
            let inner = guard.inner.take().expect("guard already released");
            std::mem::forget(guard);
            inner
        }
    }

    pub mod atomic {
        pub use std::sync::atomic::Ordering;

        use crate::util::chk::op_point;

        macro_rules! chk_atomic_int {
            ($Name:ident, $T:ty) => {
                pub struct $Name(std::sync::atomic::$Name);

                impl $Name {
                    pub const fn new(v: $T) -> $Name {
                        $Name(std::sync::atomic::$Name::new(v))
                    }
                    pub fn load(&self, o: Ordering) -> $T {
                        op_point(concat!(stringify!($Name), "::load"));
                        self.0.load(o)
                    }
                    pub fn store(&self, v: $T, o: Ordering) {
                        op_point(concat!(stringify!($Name), "::store"));
                        self.0.store(v, o)
                    }
                    pub fn swap(&self, v: $T, o: Ordering) -> $T {
                        op_point(concat!(stringify!($Name), "::swap"));
                        self.0.swap(v, o)
                    }
                    pub fn fetch_add(&self, v: $T, o: Ordering) -> $T {
                        op_point(concat!(stringify!($Name), "::fetch_add"));
                        self.0.fetch_add(v, o)
                    }
                    pub fn fetch_sub(&self, v: $T, o: Ordering) -> $T {
                        op_point(concat!(stringify!($Name), "::fetch_sub"));
                        self.0.fetch_sub(v, o)
                    }
                    pub fn fetch_max(&self, v: $T, o: Ordering) -> $T {
                        op_point(concat!(stringify!($Name), "::fetch_max"));
                        self.0.fetch_max(v, o)
                    }
                    pub fn fetch_update<F: FnMut($T) -> Option<$T>>(
                        &self,
                        set: Ordering,
                        fetch: Ordering,
                        f: F,
                    ) -> Result<$T, $T> {
                        op_point(concat!(stringify!($Name), "::fetch_update"));
                        self.0.fetch_update(set, fetch, f)
                    }
                }

                impl Default for $Name {
                    fn default() -> $Name {
                        $Name::new(0)
                    }
                }
            };
        }

        chk_atomic_int!(AtomicU8, u8);
        chk_atomic_int!(AtomicU64, u64);
        chk_atomic_int!(AtomicUsize, usize);

        pub struct AtomicBool(std::sync::atomic::AtomicBool);

        impl AtomicBool {
            pub const fn new(v: bool) -> AtomicBool {
                AtomicBool(std::sync::atomic::AtomicBool::new(v))
            }
            pub fn load(&self, o: Ordering) -> bool {
                op_point("AtomicBool::load");
                self.0.load(o)
            }
            pub fn store(&self, v: bool, o: Ordering) {
                op_point("AtomicBool::store");
                self.0.store(v, o)
            }
            pub fn swap(&self, v: bool, o: Ordering) -> bool {
                op_point("AtomicBool::swap");
                self.0.swap(v, o)
            }
        }

        impl Default for AtomicBool {
            fn default() -> AtomicBool {
                AtomicBool::new(false)
            }
        }
    }

    pub mod mpsc {
        use std::collections::VecDeque;
        use std::sync::Arc;

        pub use std::sync::mpsc::{RecvError, SendError, TryRecvError, TrySendError};

        use crate::util::chk::{fresh_obj, in_session, session, Session};

        struct ChanSt<T> {
            q: VecDeque<T>,
            senders: usize,
            rx_alive: bool,
        }

        struct Chan<T> {
            id: u64,
            /// None = unbounded (`channel`), Some = `sync_channel` cap.
            cap: Option<usize>,
            st: std::sync::Mutex<ChanSt<T>>,
        }

        impl<T> Chan<T> {
            fn new(cap: Option<usize>) -> Arc<Chan<T>> {
                Arc::new(Chan {
                    id: fresh_obj(),
                    cap,
                    st: std::sync::Mutex::new(ChanSt {
                        q: VecDeque::new(),
                        senders: 1,
                        rx_alive: true,
                    }),
                })
            }

            fn lock(&self) -> std::sync::MutexGuard<'_, ChanSt<T>> {
                self.st.lock().unwrap_or_else(|e| e.into_inner())
            }

            fn ctx(&self) -> (Arc<Session>, usize) {
                session().expect("chk channel endpoint used outside its model-check session")
            }
        }

        enum Tx<T> {
            Std(std::sync::mpsc::Sender<T>),
            Chk(Arc<Chan<T>>),
        }

        /// Unbounded sender (`channel`).
        pub struct Sender<T>(Tx<T>);

        enum STx<T> {
            Std(std::sync::mpsc::SyncSender<T>),
            Chk(Arc<Chan<T>>),
        }

        /// Bounded sender (`sync_channel`).
        pub struct SyncSender<T>(STx<T>);

        enum Rx<T> {
            Std(std::sync::mpsc::Receiver<T>),
            Chk(Arc<Chan<T>>),
        }

        pub struct Receiver<T>(Rx<T>);

        pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
            if in_session() {
                let ch = Chan::new(None);
                (Sender(Tx::Chk(Arc::clone(&ch))), Receiver(Rx::Chk(ch)))
            } else {
                let (t, r) = std::sync::mpsc::channel();
                (Sender(Tx::Std(t)), Receiver(Rx::Std(r)))
            }
        }

        pub fn sync_channel<T>(cap: usize) -> (SyncSender<T>, Receiver<T>) {
            if in_session() {
                let ch = Chan::new(Some(cap));
                (SyncSender(STx::Chk(Arc::clone(&ch))), Receiver(Rx::Chk(ch)))
            } else {
                let (t, r) = std::sync::mpsc::sync_channel(cap);
                (SyncSender(STx::Std(t)), Receiver(Rx::Std(r)))
            }
        }

        impl<T> Sender<T> {
            pub fn send(&self, t: T) -> Result<(), SendError<T>> {
                match &self.0 {
                    Tx::Std(s) => s.send(t),
                    Tx::Chk(ch) => {
                        let (sess, me) = ch.ctx();
                        sess.yield_op(me, "mpsc::send");
                        let mut st = ch.lock();
                        if !st.rx_alive {
                            return Err(SendError(t));
                        }
                        st.q.push_back(t);
                        drop(st);
                        sess.unblock_all(ch.id);
                        Ok(())
                    }
                }
            }
        }

        impl<T> Clone for Sender<T> {
            fn clone(&self) -> Sender<T> {
                match &self.0 {
                    Tx::Std(s) => Sender(Tx::Std(s.clone())),
                    Tx::Chk(ch) => {
                        ch.lock().senders += 1;
                        Sender(Tx::Chk(Arc::clone(ch)))
                    }
                }
            }
        }

        impl<T> Drop for Sender<T> {
            fn drop(&mut self) {
                if let Tx::Chk(ch) = &self.0 {
                    drop_sender(ch);
                }
            }
        }

        impl<T> SyncSender<T> {
            pub fn send(&self, t: T) -> Result<(), SendError<T>> {
                match &self.0 {
                    STx::Std(s) => s.send(t),
                    STx::Chk(ch) => {
                        let (sess, me) = ch.ctx();
                        sess.yield_op(me, "mpsc::send");
                        // Rendezvous (cap 0) is modeled as capacity 1:
                        // the repo only uses buffered channels.
                        let cap = ch.cap.unwrap_or(usize::MAX).max(1);
                        let item = t;
                        loop {
                            let mut st = ch.lock();
                            if !st.rx_alive {
                                return Err(SendError(item));
                            }
                            if st.q.len() < cap {
                                st.q.push_back(item);
                                drop(st);
                                sess.unblock_all(ch.id);
                                return Ok(());
                            }
                            drop(st);
                            sess.block_on(me, ch.id, "mpsc::send (queue full)", false);
                        }
                    }
                }
            }
        }

        impl<T> Clone for SyncSender<T> {
            fn clone(&self) -> SyncSender<T> {
                match &self.0 {
                    STx::Std(s) => SyncSender(STx::Std(s.clone())),
                    STx::Chk(ch) => {
                        ch.lock().senders += 1;
                        SyncSender(STx::Chk(Arc::clone(ch)))
                    }
                }
            }
        }

        impl<T> Drop for SyncSender<T> {
            fn drop(&mut self) {
                if let STx::Chk(ch) = &self.0 {
                    drop_sender(ch);
                }
            }
        }

        /// Shared sender-drop bookkeeping: the last sender going away
        /// wakes blocked receivers so they observe Disconnected. Never
        /// parks (safe during unwinding).
        fn drop_sender<T>(ch: &Arc<Chan<T>>) {
            let mut st = ch.lock();
            st.senders -= 1;
            let last = st.senders == 0;
            drop(st);
            if last {
                if let Some((sess, _)) = session() {
                    sess.unblock_all(ch.id);
                }
            }
        }

        impl<T> Receiver<T> {
            pub fn recv(&self) -> Result<T, RecvError> {
                match &self.0 {
                    Rx::Std(r) => r.recv(),
                    Rx::Chk(ch) => {
                        let (sess, me) = ch.ctx();
                        sess.yield_op(me, "mpsc::recv");
                        loop {
                            let mut st = ch.lock();
                            if let Some(v) = st.q.pop_front() {
                                drop(st);
                                sess.unblock_all(ch.id);
                                return Ok(v);
                            }
                            if st.senders == 0 {
                                return Err(RecvError);
                            }
                            drop(st);
                            sess.block_on(me, ch.id, "mpsc::recv (queue empty)", false);
                        }
                    }
                }
            }

            pub fn try_recv(&self) -> Result<T, TryRecvError> {
                match &self.0 {
                    Rx::Std(r) => r.try_recv(),
                    Rx::Chk(ch) => {
                        let (sess, me) = ch.ctx();
                        sess.yield_op(me, "mpsc::try_recv");
                        let mut st = ch.lock();
                        if let Some(v) = st.q.pop_front() {
                            drop(st);
                            sess.unblock_all(ch.id);
                            return Ok(v);
                        }
                        if st.senders == 0 {
                            return Err(TryRecvError::Disconnected);
                        }
                        Err(TryRecvError::Empty)
                    }
                }
            }
        }

        impl<T> Drop for Receiver<T> {
            fn drop(&mut self) {
                if let Rx::Chk(ch) = &self.0 {
                    let mut st = ch.lock();
                    st.rx_alive = false;
                    st.q.clear();
                    drop(st);
                    // Wake blocked senders so they observe the
                    // disconnect. Never parks (safe during unwinding).
                    if let Some((sess, _)) = session() {
                        sess.unblock_all(ch.id);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Tests: run in tier-1 (chk is always compiled)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::prim::atomic::{AtomicUsize, Ordering};
    use super::prim::{mpsc, Condvar, Mutex};
    use super::{check, spawn, splitmix64, Config};

    fn quick() -> Config {
        Config { max_schedules: 5_000, random_schedules: 16, ..Config::default() }
    }

    #[test]
    fn splitmix64_known_answer() {
        // Reference values for seed 0 (Vigna's splitmix64 test vector).
        let mut s = 0u64;
        assert_eq!(splitmix64(&mut s), 0xE220_A839_7B1D_CDAF);
        assert_eq!(splitmix64(&mut s), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(splitmix64(&mut s), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn atomic_increment_is_race_free() {
        let report = check(quick(), || {
            let n = Arc::new(AtomicUsize::new(0));
            let mut hs = Vec::new();
            for _ in 0..2 {
                let n2 = Arc::clone(&n);
                hs.push(spawn(move || {
                    n2.fetch_add(1, Ordering::SeqCst);
                }));
            }
            for h in hs {
                h.join();
            }
            assert_eq!(n.load(Ordering::SeqCst), 2);
        });
        report.assert_ok();
        assert!(report.dfs_complete, "tiny protocol should be exhaustible");
        assert!(report.schedules > 1, "more than one interleaving explored");
    }

    #[test]
    fn finds_lost_update_race() {
        // Classic read-modify-write race: load + store is not atomic.
        let report = check(quick(), || {
            let n = Arc::new(AtomicUsize::new(0));
            let mut hs = Vec::new();
            for _ in 0..2 {
                let n2 = Arc::clone(&n);
                hs.push(spawn(move || {
                    let v = n2.load(Ordering::SeqCst);
                    n2.store(v + 1, Ordering::SeqCst);
                }));
            }
            for h in hs {
                h.join();
            }
            assert_eq!(n.load(Ordering::SeqCst), 2);
        });
        let f = report.assert_fails();
        assert!(f.message.contains("panicked"), "lost update surfaces as a failed assert: {}", f.message);
    }

    #[test]
    fn mutex_protects_read_modify_write() {
        let report = check(quick(), || {
            let n = Arc::new(Mutex::new(0usize));
            let mut hs = Vec::new();
            for _ in 0..2 {
                let n2 = Arc::clone(&n);
                hs.push(spawn(move || {
                    let mut g = n2.lock().unwrap();
                    *g += 1;
                }));
            }
            for h in hs {
                h.join();
            }
            assert_eq!(*n.lock().unwrap(), 2);
        });
        report.assert_ok();
    }

    #[test]
    fn finds_ab_ba_deadlock() {
        let report = check(quick(), || {
            let a = Arc::new(Mutex::new(()));
            let b = Arc::new(Mutex::new(()));
            let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
            let h1 = spawn(move || {
                let _ga = a2.lock().unwrap();
                let _gb = b2.lock().unwrap();
            });
            let (a3, b3) = (Arc::clone(&a), Arc::clone(&b));
            let h2 = spawn(move || {
                let _gb = b3.lock().unwrap();
                let _ga = a3.lock().unwrap();
            });
            h1.join();
            h2.join();
        });
        let f = report.assert_fails();
        assert!(f.message.contains("deadlock"), "{}", f.message);
    }

    #[test]
    fn finds_lost_wakeup() {
        // Broken flag protocol: the setter notifies *before* the waiter
        // can be waiting, and the waiter re-checks nothing — under the
        // schedule where the notify lands first, the wait never ends.
        let report = check(quick(), || {
            let m = Arc::new(Mutex::new(false));
            let cv = Arc::new(Condvar::new());
            let (m2, cv2) = (Arc::clone(&m), Arc::clone(&cv));
            let h = spawn(move || {
                *m2.lock().unwrap() = true;
                cv2.notify_all();
            });
            {
                let g = m.lock().unwrap();
                if !*g {
                    // BROKEN: no re-check loop around the wait.
                    let _g = cv.wait(g).unwrap();
                }
            }
            h.join();
        });
        let f = report.assert_fails();
        assert!(f.message.contains("deadlock"), "{}", f.message);
    }

    #[test]
    fn correct_condvar_protocol_passes() {
        let report = check(quick(), || {
            let m = Arc::new(Mutex::new(false));
            let cv = Arc::new(Condvar::new());
            let (m2, cv2) = (Arc::clone(&m), Arc::clone(&cv));
            let h = spawn(move || {
                *m2.lock().unwrap() = true;
                cv2.notify_all();
            });
            {
                let mut g = m.lock().unwrap();
                while !*g {
                    g = cv.wait(g).unwrap();
                }
            }
            h.join();
        });
        report.assert_ok();
        assert!(report.dfs_complete);
    }

    #[test]
    fn timed_wait_escapes_missed_notify() {
        // Same broken protocol as finds_lost_wakeup, but the waiter
        // uses wait_timeout in a re-check loop: the modeled timeout
        // fires once the system is quiescent and the waiter re-checks.
        let report = check(quick(), || {
            let m = Arc::new(Mutex::new(false));
            let cv = Arc::new(Condvar::new());
            let (m2, cv2) = (Arc::clone(&m), Arc::clone(&cv));
            let h = spawn(move || {
                *m2.lock().unwrap() = true;
                cv2.notify_all();
            });
            {
                let mut g = m.lock().unwrap();
                while !*g {
                    let (ng, _res) =
                        cv.wait_timeout(g, std::time::Duration::from_millis(1)).unwrap();
                    g = ng;
                }
            }
            h.join();
        });
        report.assert_ok();
    }

    #[test]
    fn channel_backpressure_roundtrip() {
        let report = check(quick(), || {
            let (tx, rx) = mpsc::sync_channel::<usize>(1);
            let h = spawn(move || {
                for i in 0..3 {
                    tx.send(i).expect("receiver alive");
                }
            });
            for want in 0..3 {
                assert_eq!(rx.recv(), Ok(want));
            }
            assert!(rx.recv().is_err(), "sender dropped -> disconnected");
            h.join();
        });
        report.assert_ok();
    }

    #[test]
    fn channel_disconnect_unblocks_receiver() {
        let report = check(quick(), || {
            let (tx, rx) = mpsc::channel::<usize>();
            let h = spawn(move || {
                drop(tx);
            });
            // Must terminate in every schedule: either Empty-then-
            // Disconnected or an immediate disconnect.
            while rx.recv().is_ok() {}
            h.join();
        });
        report.assert_ok();
    }

    #[test]
    fn random_phase_is_reproducible() {
        let run = || {
            check(quick(), || {
                let n = Arc::new(AtomicUsize::new(0));
                let n2 = Arc::clone(&n);
                let h = spawn(move || {
                    n2.fetch_add(1, Ordering::SeqCst);
                });
                h.join();
                assert_eq!(n.load(Ordering::SeqCst), 1);
            })
        };
        let (a, b) = (run(), run());
        assert_eq!(a.schedules, b.schedules);
        assert_eq!(a.failure.is_none(), b.failure.is_none());
    }
}
