//! Synchronization facade: the single import point for every sync
//! primitive in the crate.
//!
//! In normal builds this is a pure `pub use` of `std::sync` — zero
//! cost, identical codegen, nothing wrapped. Built with
//! `RUSTFLAGS="--cfg model_check"`, the contended primitives (Mutex,
//! Condvar, atomics, mpsc channels) instead come from
//! [`crate::util::chk::prim`], whose operations become scheduling
//! points when executed on a model-checker thread (and fall through to
//! `std` everywhere else). That lets the model-check protocol tests in
//! `util/threadpool.rs`, `coordinator/state.rs`, `net/worker.rs`, and
//! `net/router.rs` drive the *production* types through exhaustive
//! schedule exploration without a second implementation.
//!
//! The `stlt lint` gate forbids `std::sync` imports anywhere else in
//! the crate, which keeps this seam honest: new concurrent code is
//! model-checkable by construction.

#[cfg(not(model_check))]
pub use std::sync::{
    atomic, mpsc, Arc, Condvar, LockResult, Mutex, MutexGuard, Once, OnceLock, PoisonError,
    WaitTimeoutResult, Weak,
};

#[cfg(model_check)]
pub use std::sync::{Arc, LockResult, Once, OnceLock, PoisonError, Weak};

#[cfg(model_check)]
pub use crate::util::chk::prim::{atomic, mpsc, Condvar, Mutex, MutexGuard, WaitTimeoutResult};
