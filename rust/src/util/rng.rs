//! Deterministic PRNG substrate (no `rand` crate offline): SplitMix64
//! seeding + xoshiro256** core, with the distribution helpers the data
//! generators need (uniform, normal, Zipf, categorical, shuffle).

/// xoshiro256** seeded via SplitMix64. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for reproducible parallel workers).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample from unnormalised weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Precomputed Zipf(alpha) sampler over [0, n) — the unigram backbone of
/// the synthetic corpus (natural-text-like rank-frequency shape).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let k = r.below(17);
            assert!(k < 17);
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(3);
        let m: f64 = (0..100_000).map(|_| r.f64()).sum::<f64>() / 100_000.0;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..100_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_rank_order() {
        let z = Zipf::new(100, 1.1);
        let mut r = Rng::new(5);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[1] > counts[50]);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(9);
        let w = [1.0, 0.0, 3.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[r.categorical(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > c[0] * 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(1);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
