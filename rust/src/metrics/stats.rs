//! Online statistics + fixed-bucket latency histogram (coordinator
//! telemetry: p50/p95/p99 request latency, throughput).

/// Welford online mean/variance.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Number of histogram slots: 200 log-spaced buckets over [1e-6, 100]
/// seconds plus an underflow (index 0) and an overflow (index 201)
/// bucket. [`crate::obs::Hist`] mirrors this geometry with atomic slots
/// and snapshots back via [`Histogram::from_buckets`], so every
/// quantile anyone reports comes from the one [`Histogram::quantile`]
/// implementation.
pub const HIST_SLOTS: usize = 202;

/// Log-spaced latency histogram from 1us to ~100s; percentile queries by
/// bucket interpolation — fixed memory, O(1) insert, good enough for
/// serving telemetry.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    total: u64,
    lo: f64,
    ratio: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        // 200 buckets, log-spaced over [1e-6, 100] seconds
        let lo = 1e-6f64;
        let hi = 100.0f64;
        let n = 200;
        Histogram { buckets: vec![0; n + 2], total: 0, lo, ratio: (hi / lo).powf(1.0 / n as f64) }
    }

    /// Rebuild a histogram from raw slot counts in [`HIST_SLOTS`]
    /// layout — the bridge back from an externally-accumulated copy of
    /// the same geometry (the atomic [`crate::obs::Hist`]).
    pub fn from_buckets(buckets: Vec<u64>) -> Self {
        assert_eq!(buckets.len(), HIST_SLOTS, "bucket layout mismatch");
        let total = buckets.iter().sum();
        let mut h = Histogram::new();
        h.buckets = buckets;
        h.total = total;
        h
    }

    /// Slot index a sample lands in (public so the atomic mirror in
    /// [`crate::obs`] records into bit-identical buckets).
    pub fn bucket_of(&self, x: f64) -> usize {
        if x < self.lo {
            return 0;
        }
        let i = ((x / self.lo).ln() / self.ratio.ln()).floor() as usize + 1;
        i.min(self.buckets.len() - 1)
    }

    pub fn record(&mut self, seconds: f64) {
        let b = self.bucket_of(seconds);
        self.buckets[b] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Approximate quantile (q in [0,1]) -> seconds (bucket lower edge).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                if i == 0 {
                    return self.lo;
                }
                return self.lo * self.ratio.powi(i as i32 - 1);
            }
        }
        self.lo * self.ratio.powi(self.buckets.len() as i32 - 2)
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} p50={:.3}ms p95={:.3}ms p99={:.3}ms",
            self.total,
            self.quantile(0.5) * 1e3,
            self.quantile(0.95) * 1e3,
            self.quantile(0.99) * 1e3
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basic() {
        let mut s = OnlineStats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-4); // 0.1ms .. 100ms
        }
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // p50 should be around 50ms (log buckets: within a factor ~1.2)
        assert!(p50 > 0.03 && p50 < 0.07, "p50={p50}");
    }

    #[test]
    fn histogram_handles_extremes() {
        let mut h = Histogram::new();
        h.record(0.0);
        h.record(1e-9);
        h.record(1e9);
        assert_eq!(h.count(), 3);
        assert!(h.quantile(1.0) >= h.quantile(0.0));
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn from_buckets_round_trips_quantiles() {
        let mut h = Histogram::new();
        for i in 1..=500 {
            h.record(i as f64 * 2e-4);
        }
        let mut raw = vec![0u64; HIST_SLOTS];
        let probe = Histogram::new();
        for i in 1..=500 {
            raw[probe.bucket_of(i as f64 * 2e-4)] += 1;
        }
        let h2 = Histogram::from_buckets(raw);
        assert_eq!(h2.count(), h.count());
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h2.quantile(q).to_bits(), h.quantile(q).to_bits());
        }
    }
}
