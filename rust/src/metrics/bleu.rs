//! Corpus BLEU-4 (Papineni et al. 2002): modified n-gram precision with
//! clipping, geometric mean over n=1..4, and brevity penalty — the
//! metric behind Table 2.

use std::collections::HashMap;

fn ngram_counts(tokens: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut m: HashMap<&[i32], usize> = HashMap::new();
    if tokens.len() >= n {
        for w in tokens.windows(n) {
            *m.entry(w).or_insert(0) += 1;
        }
    }
    m
}

/// Corpus-level BLEU-4 over (hypothesis, reference) pairs. Returns 0..100.
pub fn bleu4(pairs: &[(Vec<i32>, Vec<i32>)]) -> f64 {
    let mut match_n = [0usize; 4];
    let mut total_n = [0usize; 4];
    let mut hyp_len = 0usize;
    let mut ref_len = 0usize;
    for (hyp, rf) in pairs {
        hyp_len += hyp.len();
        ref_len += rf.len();
        for n in 1..=4 {
            let h = ngram_counts(hyp, n);
            let r = ngram_counts(rf, n);
            let total: usize = h.values().sum();
            let matched: usize = h
                .iter()
                .map(|(g, c)| (*c).min(r.get(g).copied().unwrap_or(0)))
                .sum();
            match_n[n - 1] += matched;
            total_n[n - 1] += total;
        }
    }
    // smoothed (add-epsilon) geometric mean so short corpora don't zero out
    let mut logsum = 0.0;
    for n in 0..4 {
        let p = if total_n[n] == 0 {
            return 0.0;
        } else {
            (match_n[n] as f64).max(1e-9) / total_n[n] as f64
        };
        logsum += p.ln();
    }
    let geo = (logsum / 4.0).exp();
    let bp = if hyp_len >= ref_len || hyp_len == 0 {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len as f64).exp()
    };
    100.0 * bp * geo
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(h: &[i32], r: &[i32]) -> (Vec<i32>, Vec<i32>) {
        (h.to_vec(), r.to_vec())
    }

    #[test]
    fn perfect_match_is_100() {
        let pairs = vec![p(&[1, 2, 3, 4, 5, 6], &[1, 2, 3, 4, 5, 6])];
        assert!((bleu4(&pairs) - 100.0).abs() < 1e-6);
    }

    #[test]
    fn disjoint_is_near_zero() {
        let pairs = vec![p(&[1, 2, 3, 4, 5], &[6, 7, 8, 9, 10])];
        assert!(bleu4(&pairs) < 1.0);
    }

    #[test]
    fn partial_overlap_between() {
        let pairs = vec![p(&[1, 2, 3, 9, 9, 9], &[1, 2, 3, 4, 5, 6])];
        let b = bleu4(&pairs);
        assert!(b > 0.0 && b < 100.0, "bleu {b}");
    }

    #[test]
    fn brevity_penalty_punishes_short_hyps() {
        let full = vec![p(&[1, 2, 3, 4, 5, 6, 7, 8], &[1, 2, 3, 4, 5, 6, 7, 8])];
        let short = vec![p(&[1, 2, 3, 4], &[1, 2, 3, 4, 5, 6, 7, 8])];
        assert!(bleu4(&short) < bleu4(&full));
    }

    #[test]
    fn clipping_prevents_repetition_gaming() {
        // "the the the ..." style over-generation must not score high
        let gamed = vec![p(&[1, 1, 1, 1, 1, 1], &[1, 2, 3, 4, 5, 6])];
        assert!(bleu4(&gamed) < 5.0);
    }

    #[test]
    fn corpus_pools_counts() {
        let a = vec![p(&[1, 2, 3, 4], &[1, 2, 3, 4]), p(&[9, 9], &[5, 6])];
        let b = vec![p(&[1, 2, 3, 4], &[1, 2, 3, 4])];
        assert!(bleu4(&a) < bleu4(&b));
    }
}
