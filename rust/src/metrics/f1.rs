//! SQuAD-style token F1 (bag-of-tokens precision/recall harmonic mean) —
//! the NarrativeQA metric behind Table 3.

use std::collections::HashMap;

/// Token-level F1 between a predicted and gold answer (both tokenised).
pub fn token_f1(pred: &[i32], gold: &[i32]) -> f64 {
    if pred.is_empty() && gold.is_empty() {
        return 1.0;
    }
    if pred.is_empty() || gold.is_empty() {
        return 0.0;
    }
    let mut gold_counts: HashMap<i32, usize> = HashMap::new();
    for t in gold {
        *gold_counts.entry(*t).or_insert(0) += 1;
    }
    let mut overlap = 0usize;
    for t in pred {
        if let Some(c) = gold_counts.get_mut(t) {
            if *c > 0 {
                *c -= 1;
                overlap += 1;
            }
        }
    }
    if overlap == 0 {
        return 0.0;
    }
    let precision = overlap as f64 / pred.len() as f64;
    let recall = overlap as f64 / gold.len() as f64;
    2.0 * precision * recall / (precision + recall)
}

/// Mean F1 over a set of (pred, gold) pairs, scaled to 0..100.
pub fn corpus_f1(pairs: &[(Vec<i32>, Vec<i32>)]) -> f64 {
    if pairs.is_empty() {
        return 0.0;
    }
    100.0 * pairs.iter().map(|(p, g)| token_f1(p, g)).sum::<f64>() / pairs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_match() {
        assert!((token_f1(&[1, 2, 3], &[1, 2, 3]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn no_overlap() {
        assert_eq!(token_f1(&[1, 2], &[3, 4]), 0.0);
    }

    #[test]
    fn partial() {
        // pred {1,2}, gold {2,3}: overlap 1, p=0.5, r=0.5, f1=0.5
        assert!((token_f1(&[1, 2], &[2, 3]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn multiset_clipping() {
        // repeated predictions only count up to gold multiplicity
        let f = token_f1(&[7, 7, 7, 7], &[7]);
        let p: f64 = 0.25;
        let r = 1.0;
        assert!((f - 2.0 * p * r / (p + r)).abs() < 1e-12);
    }

    #[test]
    fn empty_edge_cases() {
        assert_eq!(token_f1(&[], &[]), 1.0);
        assert_eq!(token_f1(&[], &[1]), 0.0);
        assert_eq!(token_f1(&[1], &[]), 0.0);
    }

    #[test]
    fn corpus_mean() {
        let pairs = vec![(vec![1], vec![1]), (vec![2], vec![3])];
        assert!((corpus_f1(&pairs) - 50.0).abs() < 1e-9);
    }
}
