//! Evaluation metrics: perplexity, BLEU-4, token F1, latency histograms,
//! online mean/variance. Implemented from scratch; each reproduces the
//! definition the paper's tables use (tokenised BLEU with brevity
//! penalty per Papineni et al.; SQuAD-style token F1 for NarrativeQA).

pub mod bleu;
pub mod f1;
pub mod stats;

pub use bleu::bleu4;
pub use f1::token_f1;
pub use stats::{Histogram, OnlineStats};

/// Perplexity from summed negative log-likelihood (nats) and token count.
pub fn perplexity(nll_sum: f64, count: f64) -> f64 {
    if count <= 0.0 {
        return f64::NAN;
    }
    (nll_sum / count).exp()
}

/// Numerically-stable log-softmax over a logits row (host-side scoring).
pub fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = logits.iter().map(|x| (x - max).exp()).sum::<f32>().ln() + max;
    logits.iter().map(|x| x - lse).collect()
}

/// argmax for greedy decoding.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if *x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ppl_of_uniform_model() {
        // uniform over V => nll = ln V per token => ppl = V
        let v = 256.0f64;
        let nll = v.ln() * 100.0;
        assert!((perplexity(nll, 100.0) - v).abs() < 1e-6);
    }

    #[test]
    fn log_softmax_normalises() {
        let ls = log_softmax(&[1.0, 2.0, 3.0]);
        let total: f32 = ls.iter().map(|x| x.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
        assert!(ls[2] > ls[1] && ls[1] > ls[0]);
    }

    #[test]
    fn log_softmax_shift_invariant() {
        let a = log_softmax(&[1.0, 2.0, 3.0]);
        let b = log_softmax(&[101.0, 102.0, 103.0]);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 5.0, -2.0]), 1);
        assert_eq!(argmax(&[3.0]), 0);
    }
}
