//! Interpretability (§4.5): locate the learned STLT parameters inside
//! the flat packed vector and report half-lives, frequencies and window
//! bandwidths per layer.
//!
//! The packing order mirrors python/compile/optim.py exactly: a
//! path-sorted walk of the nested param dict (lists indexed by 3-digit
//! position). The layout is pure arithmetic over the ModelConfig, so no
//! Python is needed at inspection time. Validated against the python
//! packer by rust/tests/integration_runtime.rs.

use crate::runtime::artifact::ModelConfig;

/// One named leaf in packing order.
#[derive(Clone, Debug, PartialEq)]
pub struct Leaf {
    pub path: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl Leaf {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Packing layout for the decoder-only trunk (trunk.init).
pub fn trunk_layout(cfg: &ModelConfig) -> Vec<Leaf> {
    let d = cfg.d_model;
    let s = cfg.s_max;
    let v = cfg.vocab;
    let h = d * cfg.ffn_mult.max(1); // python default ffn_mult = 4
    let mut leaves: Vec<(String, Vec<usize>)> = Vec::new();
    leaves.push(("/embed".into(), vec![v, d]));
    for li in 0..cfg.n_layers {
        let p = format!("/layers/{li:03}");
        // sorted keys within a layer dict
        leaves.push((format!("{p}/ffn_b1"), vec![h]));
        leaves.push((format!("{p}/ffn_b2"), vec![d]));
        leaves.push((format!("{p}/ffn_w1"), vec![d, h]));
        leaves.push((format!("{p}/ffn_w2"), vec![h, d]));
        leaves.push((format!("{p}/ln1_b"), vec![d]));
        leaves.push((format!("{p}/ln1_g"), vec![d]));
        leaves.push((format!("{p}/ln2_b"), vec![d]));
        leaves.push((format!("{p}/ln2_g"), vec![d]));
        // mixer dict (sorted keys), depends on arch
        match cfg.arch.as_str() {
            "stlt" => {
                if cfg.adaptive {
                    leaves.push((format!("{p}/mixer/b_alpha"), vec![s]));
                }
                leaves.push((format!("{p}/mixer/omega"), vec![s]));
                leaves.push((format!("{p}/mixer/sigma_raw"), vec![s]));
                leaves.push((format!("{p}/mixer/t_raw"), vec![1]));
                if cfg.adaptive {
                    leaves.push((format!("{p}/mixer/w_alpha"), vec![d, s]));
                }
                leaves.push((format!("{p}/mixer/w_f"), vec![d, s]));
                leaves.push((format!("{p}/mixer/w_o"), vec![d, d]));
                leaves.push((format!("{p}/mixer/w_v"), vec![d, d]));
            }
            "vanilla" | "performer" => {
                for k in ["w_k", "w_o", "w_q", "w_v"] {
                    leaves.push((format!("{p}/mixer/{k}"), vec![d, d]));
                }
            }
            "linformer" => {
                leaves.push((format!("{p}/mixer/e_proj"), vec![cfg.n_ctx, 32]));
                for k in ["w_k", "w_o", "w_q", "w_v"] {
                    leaves.push((format!("{p}/mixer/{k}"), vec![d, d]));
                }
            }
            "fnet" => {
                leaves.push((format!("{p}/mixer/w_f"), vec![d, s]));
                leaves.push((format!("{p}/mixer/w_o"), vec![d, d]));
                leaves.push((format!("{p}/mixer/w_v"), vec![d, d]));
            }
            "ssm" => {
                leaves.push((format!("{p}/mixer/d_skip"), vec![d]));
                leaves.push((format!("{p}/mixer/omega"), vec![d]));
                leaves.push((format!("{p}/mixer/sigma_raw"), vec![d]));
                leaves.push((format!("{p}/mixer/w_in"), vec![d, d]));
                leaves.push((format!("{p}/mixer/w_o"), vec![d, d]));
            }
            _ => {}
        }
    }
    leaves.push(("/lnf_b".into(), vec![d]));
    leaves.push(("/lnf_g".into(), vec![d]));
    let mut out = Vec::with_capacity(leaves.len());
    let mut off = 0usize;
    for (path, shape) in leaves {
        let n: usize = shape.iter().product::<usize>().max(1);
        out.push(Leaf { path, shape, offset: off });
        off += n;
    }
    out
}

pub fn total_params(layout: &[Leaf]) -> usize {
    layout.last().map(|l| l.offset + l.numel()).unwrap_or(0)
}

fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else {
        (1.0 + x.exp()).ln()
    }
}

/// Learned node parameters of one STLT layer.
#[derive(Clone, Debug)]
pub struct LayerNodes {
    pub layer: usize,
    pub sigma: Vec<f32>,
    pub omega: Vec<f32>,
    pub t: f32,
    pub half_lives: Vec<f32>,
}

pub fn extract_nodes(flat: &[f32], cfg: &ModelConfig) -> Vec<LayerNodes> {
    let layout = trunk_layout(cfg);
    let find = |path: &str| layout.iter().find(|l| l.path == path);
    let mut out = Vec::new();
    for li in 0..cfg.n_layers {
        let p = format!("/layers/{li:03}/mixer");
        let (Some(sr), Some(om), Some(tr)) = (
            find(&format!("{p}/sigma_raw")),
            find(&format!("{p}/omega")),
            find(&format!("{p}/t_raw")),
        ) else {
            continue;
        };
        let sigma: Vec<f32> = flat[sr.offset..sr.offset + sr.numel()]
            .iter()
            .map(|&x| softplus(x) + 1e-3)
            .collect();
        let omega: Vec<f32> = flat[om.offset..om.offset + om.numel()].to_vec();
        let t = softplus(flat[tr.offset]) + 1.0;
        let half_lives = sigma.iter().map(|&s| (2.0f32).ln() / s).collect();
        out.push(LayerNodes { layer: li, sigma, omega, t, half_lives });
    }
    out
}

/// Human-readable §4.5 report.
pub fn inspect_stlt_params(flat: &[f32], cfg: &ModelConfig) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let nodes = extract_nodes(flat, cfg);
    if nodes.is_empty() {
        return format!("arch '{}' has no STLT node parameters", cfg.arch);
    }
    let _ = writeln!(s, "STLT learned parameters ({} layers, S={}):", cfg.n_layers, cfg.s_max);
    for ln in &nodes {
        let mut sig = ln.sigma.clone();
        sig.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = sig[sig.len() / 2];
        let hl_max = ln.half_lives.iter().cloned().fold(0.0f32, f32::max);
        let osc = ln.omega.iter().filter(|&&w| w.abs() > 0.05).count();
        let _ = writeln!(
            s,
            "  layer {}: T={:7.2}  sigma[min={:.4} med={:.4} max={:.4}]  \
             half-life[max={:7.1} tokens]  oscillating nodes {}/{}",
            ln.layer,
            ln.t,
            sig[0],
            med,
            sig[sig.len() - 1],
            hl_max,
            osc,
            ln.omega.len()
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            arch: "stlt".into(),
            vocab: 256,
            d_model: 64,
            n_layers: 2,
            n_ctx: 128,
            s_max: 32,
            batch: 8,
            adaptive: false,
            mode: "linear".into(),
            total_steps: 2000,
            ..ModelConfig::default()
        }
    }

    #[test]
    fn layout_is_contiguous_and_sorted() {
        let l = trunk_layout(&cfg());
        for w in l.windows(2) {
            assert_eq!(w[0].offset + w[0].numel(), w[1].offset, "{:?}", w);
        }
        assert!(l[0].path == "/embed");
    }

    #[test]
    fn extract_nodes_reads_offsets() {
        let c = cfg();
        let layout = trunk_layout(&c);
        let total = total_params(&layout);
        let mut flat = vec![0.0f32; total];
        // write a recognisable sigma_raw in layer 1
        let leaf = layout.iter().find(|l| l.path == "/layers/001/mixer/sigma_raw").unwrap();
        for (i, x) in flat[leaf.offset..leaf.offset + leaf.numel()].iter_mut().enumerate() {
            *x = i as f32 * 0.1;
        }
        let nodes = extract_nodes(&flat, &c);
        assert_eq!(nodes.len(), 2);
        assert!(nodes[1].sigma[5] > nodes[1].sigma[0]);
        assert_eq!(nodes[0].half_lives.len(), 32);
    }

    #[test]
    fn adaptive_layout_has_gate_params() {
        let mut c = cfg();
        c.adaptive = true;
        c.s_max = 64;
        let l = trunk_layout(&c);
        assert!(l.iter().any(|x| x.path == "/layers/000/mixer/b_alpha"));
        assert!(l.iter().any(|x| x.path == "/layers/000/mixer/w_alpha"));
    }

    #[test]
    fn report_renders() {
        let c = cfg();
        let total = total_params(&trunk_layout(&c));
        let s = inspect_stlt_params(&vec![0.1; total], &c);
        assert!(s.contains("layer 0"));
        assert!(s.contains("half-life"));
    }
}
